"""Typed eBPF maps: structured, concurrent cross-plugin state.

This is the composability substrate of the paper (§3, T2): profiler programs
write telemetry, tuner programs read it, through *typed* maps with atomic
access semantics — no ad hoc shared memory, no locking bugs in policy code.

Map kinds (mirroring the kernel):
  * ARRAY   — fixed number of slots, u32 key = index, preallocated values.
  * HASH    — bounded-capacity hash map, fixed-size keys.
  * PERCPU_ARRAY — one array per "cpu" (here: per host thread slot), for
    contention-free counters aggregated on read.

Keys and values are fixed-size byte strings; the verifier checks that policy
programs pass correctly-sized stack buffers.  Host-side code uses the typed
``lookup_u64``/``update_u64`` convenience accessors.

Concurrency — the mutation contract:

  * ``lookup()`` (and the typed host accessors built on it) **copies the
    value out under the per-map lock**: cross-thread callers get a
    consistent snapshot that can never tear mid-``update()`` and whose
    mutation cannot alias map storage.
  * ``lookup_ref()`` returns the **live** backing bytearray — the
    kernel-eBPF "pointer to the value slot".  Only the execution tiers
    (VM / JIT) use it; direct pointer stores through it are tear-free
    per 8-byte slot (GIL + single slice assignment), matching the kernel
    model where racing element writes are allowed per-slot.
  * every multi-slot **writeback path holds the per-map lock** —
    ``update()``, ``update_u64()``, and the tiers' read-modify-write
    helpers (``ema_update``) — so host readers can never observe a
    half-applied multi-slot value or lose an update to an unlocked RMW.
  * host code composing its own read-modify-write transactions takes
    :attr:`BpfMap.lock` explicitly (an RLock, so the typed accessors
    nest inside it).
"""

from __future__ import annotations

import struct
import threading
from typing import Dict, Iterator, Optional

U64 = (1 << 64) - 1


class MapError(Exception):
    pass


class BpfMap:
    """Base class.  Values live in one backing bytearray per element."""

    kind = "base"

    def __init__(self, name: str, key_size: int, value_size: int, max_entries: int):
        if key_size <= 0 or value_size <= 0 or max_entries <= 0:
            raise MapError(f"map {name}: sizes must be positive")
        self.name = name
        self.key_size = key_size
        self.value_size = value_size
        self.max_entries = max_entries
        # reentrant: typed accessors (update_u64) compose lookup+update
        # under one critical section
        self._lock = threading.RLock()
        # monotone content-version counter: bumped by every mutation on
        # the structured surface (update / update_u64 / delete), by the
        # execution tiers' helper writebacks, AND by the runtime tiers'
        # store instructions through map-value pointers (the VM tags the
        # pointer with its owning map; the v2 JIT emits a touch at every
        # verified map store; the legacy v1 JIT touches through its
        # region table's owner column).  Device-resident bridge caches
        # (pallasc.DeviceBridge) key their uploads off it, so a clean
        # map never round-trips.  NOT tracked: host code writing through
        # raw lookup_ref views; such writers call touch() /
        # bridge.invalidate() explicitly.
        self._version = 0

    @property
    def lock(self) -> threading.RLock:
        """The per-map mutex every writeback path holds; host callers
        composing their own read-modify-write transactions take it too."""
        return self._lock

    @property
    def version(self) -> int:
        """Content version — changes iff the map was mutated through the
        tracked surface since last observed."""
        return self._version

    def touch(self) -> None:
        """Mark the map contents changed (for mutations done through raw
        ``lookup_ref`` pointers that the tracked surface cannot see)."""
        with self._lock:
            self._version += 1

    # -- raw interface -----------------------------------------------------
    def lookup(self, key: bytes) -> Optional[bytearray]:
        """Copy-out lookup for cross-thread (host-side) callers.

        The copy is taken under the map lock, so it can never tear
        against a lock-held writeback, and mutating it cannot alias map
        storage.  Execution tiers use :meth:`lookup_ref` for kernel-style
        pointer semantics."""
        with self._lock:
            v = self.lookup_ref(key)
            return None if v is None else bytearray(v)

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        """Live view of the value cell (the eBPF value pointer) — VM/JIT
        tiers only.  Single-slot stores through it are GIL-atomic;
        multi-slot writebacks must hold :attr:`lock`."""
        raise NotImplementedError

    def update(self, key: bytes, value: bytes) -> int:
        raise NotImplementedError

    def delete(self, key: bytes) -> int:
        raise NotImplementedError

    def keys(self) -> Iterator[bytes]:
        raise NotImplementedError

    def _check_key(self, key: bytes) -> None:
        if len(key) != self.key_size:
            raise MapError(
                f"map {self.name}: key size {len(key)} != {self.key_size}")

    def _check_value(self, value: bytes) -> None:
        if len(value) != self.value_size:
            raise MapError(
                f"map {self.name}: value size {len(value)} != {self.value_size}")

    # -- typed convenience (host side) -------------------------------------
    def lookup_u64(self, key: int, slot: int = 0) -> Optional[int]:
        v = self.lookup(struct.pack("<I", key) if self.key_size == 4
                        else struct.pack("<Q", key))
        if v is None:
            return None
        return struct.unpack_from("<Q", v, slot * 8)[0]

    def update_u64(self, key: int, value: int, slot: int = 0) -> None:
        kb = struct.pack("<I", key) if self.key_size == 4 else struct.pack("<Q", key)
        # lock-held writeback through the live view (lookup_ref, not the
        # copy-out lookup: pack_into on a copy would silently drop the
        # write)
        with self._lock:
            v = self.lookup_ref(kb)
            if v is None:
                buf = bytearray(self.value_size)
                struct.pack_into("<Q", buf, slot * 8, value & U64)
                self.update(kb, bytes(buf))
            else:
                struct.pack_into("<Q", v, slot * 8, value & U64)
                self._version += 1

    def snapshot(self) -> Dict[bytes, bytes]:
        with self._lock:
            return {bytes(k): bytes(self.lookup_ref(k))
                    for k in list(self.keys())}


class ArrayMap(BpfMap):
    kind = "array"

    def __init__(self, name: str, value_size: int, max_entries: int):
        super().__init__(name, 4, value_size, max_entries)
        self._slots = [bytearray(value_size) for _ in range(max_entries)]

    def _index(self, key: bytes) -> Optional[int]:
        self._check_key(key)
        idx = struct.unpack("<I", key)[0]
        return idx if idx < self.max_entries else None

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        idx = self._index(key)
        return None if idx is None else self._slots[idx]

    def update(self, key: bytes, value: bytes) -> int:
        self._check_value(value)
        idx = self._index(key)
        if idx is None:
            return -1
        with self._lock:
            self._slots[idx][:] = value
            self._version += 1
        return 0

    def delete(self, key: bytes) -> int:
        # Array maps cannot delete (kernel semantics: -EINVAL).
        return -1

    def keys(self) -> Iterator[bytes]:
        for i in range(self.max_entries):
            yield struct.pack("<I", i)


class HashMap(BpfMap):
    kind = "hash"

    def __init__(self, name: str, key_size: int, value_size: int, max_entries: int):
        super().__init__(name, key_size, value_size, max_entries)
        self._table: Dict[bytes, bytearray] = {}

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        self._check_key(key)
        return self._table.get(bytes(key))

    def update(self, key: bytes, value: bytes) -> int:
        self._check_key(key)
        self._check_value(value)
        kb = bytes(key)
        with self._lock:
            if kb not in self._table and len(self._table) >= self.max_entries:
                return -1  # E2BIG
            slot = self._table.setdefault(kb, bytearray(self.value_size))
            slot[:] = value
            self._version += 1
        return 0

    def delete(self, key: bytes) -> int:
        self._check_key(key)
        with self._lock:
            if self._table.pop(bytes(key), None) is None:
                return -1
            self._version += 1
            return 0

    def keys(self) -> Iterator[bytes]:
        return iter(list(self._table.keys()))


class PerCpuArrayMap(ArrayMap):
    """Per-thread-slot array; reads aggregate by sum (counter idiom)."""

    kind = "percpu_array"
    N_SLOTS = 8

    def __init__(self, name: str, value_size: int, max_entries: int):
        super().__init__(name, value_size, max_entries)
        self._cpu_slots = [
            [bytearray(value_size) for _ in range(max_entries)]
            for _ in range(self.N_SLOTS)
        ]
        self._tls = threading.local()

    def _cpu(self) -> int:
        cpu = getattr(self._tls, "cpu", None)
        if cpu is None:
            cpu = threading.get_ident() % self.N_SLOTS
            self._tls.cpu = cpu
        return cpu

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        idx = self._index(key)
        return None if idx is None else self._cpu_slots[self._cpu()][idx]

    def aggregate_u64(self, key: int, slot: int = 0) -> int:
        idx = struct.unpack("<I", struct.pack("<I", key))[0]
        if idx >= self.max_entries:
            raise MapError(f"{self.name}: key {key} out of range")
        total = 0
        for cpu in range(self.N_SLOTS):
            total += struct.unpack_from("<Q", self._cpu_slots[cpu][idx], slot * 8)[0]
        return total & U64


MAP_KINDS = {
    "array": ArrayMap,
    "hash": HashMap,
    "percpu_array": PerCpuArrayMap,
}


class MapRegistry:
    """Named maps shared across programs — the composability namespace.

    Two tiers of sharing:

    * every created map is reachable by name through :meth:`get` while the
      registry lives — incidental sharing within one runtime;
    * **pinned** maps (:meth:`pin` / :meth:`get_pinned`) form an explicit
      namespace, the bpffs-pin analogue: a profiler program declares its
      EMA map ``shared=True`` and a tuner program (or host-side tooling)
      finds the same object by name, without ever holding a program
      reference.  Pinned maps survive every program detach/replace.
    """

    def __init__(self):
        self._maps: Dict[str, BpfMap] = {}
        self._pinned: Dict[str, BpfMap] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _shape_of(kind: str, key_size: int, value_size: int,
                  max_entries: int) -> tuple:
        # array-family maps force u32 keys regardless of the declaration
        return (kind, key_size if kind == "hash" else 4, value_size,
                max_entries)

    def validate(self, name: str, kind: str, *, key_size: int = 4,
                 value_size: int = 8, max_entries: int = 64) -> None:
        """Shape-check a declaration against the registry WITHOUT creating
        anything — the dry-run half of a transactional bundle load."""
        if kind not in MAP_KINDS:
            raise MapError(f"unknown map kind {kind!r}")
        with self._lock:
            m = self._maps.get(name)
            if m is not None and (m.kind, m.key_size, m.value_size,
                                  m.max_entries) != self._shape_of(
                                      kind, key_size, value_size, max_entries):
                raise MapError(f"map {name}: redefinition with different shape")

    def create(self, name: str, kind: str, *, key_size: int = 4,
               value_size: int = 8, max_entries: int = 64) -> BpfMap:
        with self._lock:
            if name in self._maps:
                m = self._maps[name]
                if (m.kind, m.key_size, m.value_size, m.max_entries) != \
                        self._shape_of(kind, key_size, value_size, max_entries):
                    raise MapError(f"map {name}: redefinition with different shape")
                return m
            if kind == "hash":
                m = HashMap(name, key_size, value_size, max_entries)
            elif kind in ("array", "percpu_array"):
                m = MAP_KINDS[kind](name, value_size, max_entries)
            else:
                raise MapError(f"unknown map kind {kind!r}")
            self._maps[name] = m
            return m

    def get(self, name: str) -> BpfMap:
        try:
            return self._maps[name]
        except KeyError:
            raise MapError(f"map {name!r} not found") from None

    # ---- pinned namespace (cross-plugin maps, the bpffs-pin analogue) ----
    def pin(self, name: str) -> BpfMap:
        """Pin an existing map into the shared namespace (idempotent)."""
        with self._lock:
            try:
                m = self._maps[name]
            except KeyError:
                raise MapError(
                    f"cannot pin {name!r}: map not found") from None
            self._pinned[name] = m
            return m

    def get_pinned(self, name: str) -> BpfMap:
        try:
            return self._pinned[name]
        except KeyError:
            raise MapError(
                f"map {name!r} is not pinned; pinned maps: "
                f"{sorted(self._pinned) or 'none'}") from None

    def unpin(self, name: str) -> None:
        with self._lock:
            if self._pinned.pop(name, None) is None:
                raise MapError(f"map {name!r} is not pinned")

    def is_pinned(self, name: str) -> bool:
        return name in self._pinned

    def pinned_names(self):
        return sorted(self._pinned)

    def __contains__(self, name: str) -> bool:
        return name in self._maps

    def names(self):
        return list(self._maps)
