"""Typed eBPF maps: structured, concurrent cross-plugin state.

This is the composability substrate of the paper (§3, T2): profiler programs
write telemetry, tuner programs read it, through *typed* maps with atomic
access semantics — no ad hoc shared memory, no locking bugs in policy code.

Map kinds (mirroring the kernel):
  * ARRAY   — fixed number of slots, u32 key = index, preallocated values.
  * HASH    — bounded-capacity hash map, fixed-size keys.
  * PERCPU_ARRAY — one array per "cpu" (here: per host thread slot), for
    contention-free counters aggregated on read.
  * RINGBUF — bounded MPSC event stream (the observability plane's
    spine): programs ``reserve``/``submit`` fixed-size records, host
    consumers ``drain()`` them FIFO; a full ring drops the NEW record
    and counts it (``drops``).  Cursors are free-running u64s, so the
    same state machine lowers to the in-graph tiers with the control
    words appended to the value array (see :func:`device_shape`).
  * PERDEV_ARRAY — one array shard per device index with a host-side
    merge view; the in-graph tiers see the *current* shard, so the
    lowering is exactly the array lowering.
  * LRU_HASH — fixed-capacity hash with clock/LRU eviction: ``update``
    on a full map evicts the least-recently-used entry instead of
    failing, and every lookup/update refreshes the entry's recency.

Keys and values are fixed-size byte strings; the verifier checks that policy
programs pass correctly-sized stack buffers.  Host-side code uses the typed
``lookup_u64``/``update_u64`` convenience accessors.

Concurrency — the mutation contract:

  * ``lookup()`` (and the typed host accessors built on it) **copies the
    value out under the per-map lock**: cross-thread callers get a
    consistent snapshot that can never tear mid-``update()`` and whose
    mutation cannot alias map storage.
  * ``lookup_ref()`` returns the **live** backing bytearray — the
    kernel-eBPF "pointer to the value slot".  Only the execution tiers
    (VM / JIT) use it; direct pointer stores through it are tear-free
    per 8-byte slot (GIL + single slice assignment), matching the kernel
    model where racing element writes are allowed per-slot.
  * every multi-slot **writeback path holds the per-map lock** —
    ``update()``, ``update_u64()``, and the tiers' read-modify-write
    helpers (``ema_update``) — so host readers can never observe a
    half-applied multi-slot value or lose an update to an unlocked RMW.
  * host code composing its own read-modify-write transactions takes
    :attr:`BpfMap.lock` explicitly (an RLock, so the typed accessors
    nest inside it).
"""

from __future__ import annotations

import ctypes
import struct
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

U64 = (1 << 64) - 1


def device_shape(kind: str, value_size: int, max_entries: int) -> tuple:
    """uint64 device-array shape ``(rows, cols)`` for one map.

    The in-graph tiers (jaxc / pallas / pallas32) carry every map as one
    dense uint64 array; kinds with cursor/recency state append it to the
    same array so the kernel harness and the bridge stay kind-agnostic:

      * array-family — ``(max_entries, value_size // 8)``
      * ringbuf — record rows plus control rows holding the four control
        words ``head, tail, drops, pending`` (packed ``value_size // 8``
        words per row)
      * hash — fixed-capacity open-addressing table: each row is
        ``[values..., key, used]`` (linear probing over
        ``(key_lo ^ key_hi) % max_entries``, tombstone-free) and one
        trailing control row holds the occupancy counter
      * lru_hash — each row is ``[values..., key, recency]`` and one
        trailing control row holds the clock

    The verifier bounds map-value pointers to ``value_size``, so policy
    code can never reach the appended control state."""
    slots = max(1, value_size // 8)
    if kind == "ringbuf":
        ctl_rows = -(-4 // slots)           # ceil(4 / slots)
        return (max_entries + ctl_rows, slots)
    if kind in ("hash", "lru_hash"):
        return (max_entries + 1, slots + 2)
    return (max_entries, slots)


def hash_slot(key: int, max_entries: int) -> int:
    """Home slot of ``key`` in the open-addressing device table.

    Folding the halves keeps the modulus in 32 bits, so the pair-form
    (lo, hi) lowering computes the identical slot with ONE uint32 mod:
    ``(key_lo ^ key_hi) % max_entries``."""
    return ((key & 0xFFFFFFFF) ^ (key >> 32)) % max_entries


class MapError(Exception):
    pass


class BpfMap:
    """Base class.  Values live in one backing bytearray per element."""

    kind = "base"

    def __init__(self, name: str, key_size: int, value_size: int, max_entries: int):
        if key_size <= 0 or value_size <= 0 or max_entries <= 0:
            raise MapError(f"map {name}: sizes must be positive")
        self.name = name
        self.key_size = key_size
        self.value_size = value_size
        self.max_entries = max_entries
        # reentrant: typed accessors (update_u64) compose lookup+update
        # under one critical section
        self._lock = threading.RLock()
        # monotone content-version counter: bumped by every mutation on
        # the structured surface (update / update_u64 / delete), by the
        # execution tiers' helper writebacks, AND by the runtime tiers'
        # store instructions through map-value pointers (the VM tags the
        # pointer with its owning map; the v2 JIT emits a touch at every
        # verified map store; the legacy v1 JIT touches through its
        # region table's owner column).  Device-resident bridge caches
        # (pallasc.DeviceBridge) key their uploads off it, so a clean
        # map never round-trips.  NOT tracked: host code writing through
        # raw lookup_ref views; such writers call touch() /
        # bridge.invalidate() explicitly.
        self._version = 0
        # native-tier mutation counter: compiled code bumps this cell with
        # one machine increment at call exit (per dirty map) instead of
        # calling back into Python.  ``version`` reads the sum, so bridge
        # caches observe native mutations exactly like touch()ed ones.
        self._native_bumps = (ctypes.c_uint64 * 1)(0)

    @property
    def lock(self) -> threading.RLock:
        """The per-map mutex every writeback path holds; host callers
        composing their own read-modify-write transactions take it too."""
        return self._lock

    @property
    def version(self) -> int:
        """Content version — changes iff the map was mutated through the
        tracked surface since last observed."""
        return self._version + self._native_bumps[0]

    def touch(self) -> None:
        """Mark the map contents changed (for mutations done through raw
        ``lookup_ref`` pointers that the tracked surface cannot see)."""
        with self._lock:
            self._version += 1

    def native_view(self) -> "NativeMapView":
        """Stable C-ABI view for the native tier (array family only);
        other kinds route through Python helper handlers."""
        raise MapError(
            f"map {self.name} (kind {self.kind}) has no native view")

    # -- raw interface -----------------------------------------------------
    def lookup(self, key: bytes) -> Optional[bytearray]:
        """Copy-out lookup for cross-thread (host-side) callers.

        The copy is taken under the map lock, so it can never tear
        against a lock-held writeback, and mutating it cannot alias map
        storage.  Execution tiers use :meth:`lookup_ref` for kernel-style
        pointer semantics."""
        with self._lock:
            v = self.lookup_ref(key)
            return None if v is None else bytearray(v)

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        """Live view of the value cell (the eBPF value pointer) — VM/JIT
        tiers only.  Single-slot stores through it are GIL-atomic;
        multi-slot writebacks must hold :attr:`lock`."""
        raise NotImplementedError

    def update(self, key: bytes, value: bytes) -> int:
        raise NotImplementedError

    def delete(self, key: bytes) -> int:
        raise NotImplementedError

    def keys(self) -> Iterator[bytes]:
        raise NotImplementedError

    def _check_key(self, key: bytes) -> None:
        if len(key) != self.key_size:
            raise MapError(
                f"map {self.name}: key size {len(key)} != {self.key_size}")

    def _check_value(self, value: bytes) -> None:
        if len(value) != self.value_size:
            raise MapError(
                f"map {self.name}: value size {len(value)} != {self.value_size}")

    # -- typed convenience (host side) -------------------------------------
    def lookup_u64(self, key: int, slot: int = 0) -> Optional[int]:
        v = self.lookup(struct.pack("<I", key) if self.key_size == 4
                        else struct.pack("<Q", key))
        if v is None:
            return None
        return struct.unpack_from("<Q", v, slot * 8)[0]

    def update_u64(self, key: int, value: int, slot: int = 0) -> None:
        kb = struct.pack("<I", key) if self.key_size == 4 else struct.pack("<Q", key)
        # lock-held writeback through the live view (lookup_ref, not the
        # copy-out lookup: pack_into on a copy would silently drop the
        # write)
        with self._lock:
            v = self.lookup_ref(kb)
            if v is None:
                buf = bytearray(self.value_size)
                struct.pack_into("<Q", buf, slot * 8, value & U64)
                self.update(kb, bytes(buf))
            else:
                struct.pack_into("<Q", v, slot * 8, value & U64)
                self._version += 1

    def snapshot(self) -> Dict[bytes, bytes]:
        with self._lock:
            return {bytes(k): bytes(self.lookup_ref(k))
                    for k in list(self.keys())}

    # -- in-graph device protocol ------------------------------------------
    # The jaxc/pallas tiers move map state as dense uint64 arrays shaped
    # by device_shape(); each kind packs/unpacks its own layout so the
    # bridge and the kernel harness never branch on map kind.
    def device_shape(self) -> tuple:
        return device_shape(self.kind, self.value_size, self.max_entries)

    def to_device(self) -> "np.ndarray":
        raise MapError(f"map {self.name} (kind {self.kind}) has no "
                       "in-graph device representation")

    def from_device(self, arr) -> None:
        raise MapError(f"map {self.name} (kind {self.kind}) has no "
                       "in-graph device representation")


class ArrayMap(BpfMap):
    kind = "array"

    def __init__(self, name: str, value_size: int, max_entries: int):
        super().__init__(name, 4, value_size, max_entries)
        self._slots = [bytearray(value_size) for _ in range(max_entries)]

    def _live_slots(self) -> List[bytearray]:
        """The slot list the execution tiers (and the device protocol)
        see — subclasses with sharded storage override this."""
        return self._slots

    def to_device(self) -> np.ndarray:
        with self._lock:
            flat = b"".join(bytes(s) for s in self._live_slots())
        return np.frombuffer(flat, dtype="<u8").reshape(
            self.max_entries, self.value_size // 8).copy()

    def from_device(self, arr) -> None:
        data = np.ascontiguousarray(np.asarray(arr, dtype="<u8")).tobytes()
        vs = self.value_size
        with self._lock:
            for i, s in enumerate(self._live_slots()):
                s[:] = data[i * vs:(i + 1) * vs]
            self._version += 1

    def _index(self, key: bytes) -> Optional[int]:
        self._check_key(key)
        idx = struct.unpack("<I", key)[0]
        return idx if idx < self.max_entries else None

    def native_view(self) -> "NativeMapView":
        with self._lock:
            v = getattr(self, "_native_view", None)
            if v is None:
                v = self._native_view = NativeMapView(self)
            return v

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        idx = self._index(key)
        return None if idx is None else self._slots[idx]

    def update(self, key: bytes, value: bytes) -> int:
        self._check_value(value)
        idx = self._index(key)
        if idx is None:
            return -1
        with self._lock:
            self._slots[idx][:] = value
            self._version += 1
        return 0

    def delete(self, key: bytes) -> int:
        # Array maps cannot delete (kernel semantics: -EINVAL).
        return -1

    def keys(self) -> Iterator[bytes]:
        for i in range(self.max_entries):
            yield struct.pack("<I", i)


class HashMap(BpfMap):
    kind = "hash"

    def __init__(self, name: str, key_size: int, value_size: int, max_entries: int):
        super().__init__(name, key_size, value_size, max_entries)
        self._table: Dict[bytes, bytearray] = {}

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        self._check_key(key)
        return self._table.get(bytes(key))

    def update(self, key: bytes, value: bytes) -> int:
        self._check_key(key)
        self._check_value(value)
        kb = bytes(key)
        with self._lock:
            if kb not in self._table and len(self._table) >= self.max_entries:
                return -1  # E2BIG
            slot = self._table.setdefault(kb, bytearray(self.value_size))
            slot[:] = value
            self._version += 1
        return 0

    def delete(self, key: bytes) -> int:
        self._check_key(key)
        with self._lock:
            if self._table.pop(bytes(key), None) is None:
                return -1
            self._version += 1
            return 0

    def keys(self) -> Iterator[bytes]:
        return iter(list(self._table.keys()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._table)

    # -- in-graph device protocol ------------------------------------------
    # Open-addressing table: max_entries rows of [values..., key, used]
    # plus a control row holding the occupancy count.  Upload repacks the
    # host dict canonically (insertion order, each key at its home slot
    # ``hash_slot(key, cap)`` then linear-probed to the first free row),
    # so probe chains never contain holes: the host surface may delete,
    # but in-graph execution is insert/update-only (tombstone-free) and
    # every upload starts from a compacted table.
    def to_device(self) -> np.ndarray:
        rows, cols = self.device_shape()
        slots = cols - 2
        cap = self.max_entries
        with self._lock:
            arr = np.zeros((rows, cols), dtype="<u8")
            for kb, val in self._table.items():
                k = int.from_bytes(kb, "little")
                i = hash_slot(k, cap)
                while arr[i, slots + 1] != 0:
                    i = (i + 1) % cap
                arr[i, :slots] = np.frombuffer(bytes(val), dtype="<u8")
                arr[i, slots] = k
                arr[i, slots + 1] = 1
            arr[cap, 0] = len(self._table)
        return arr

    def from_device(self, arr) -> None:
        a = np.ascontiguousarray(np.asarray(arr, dtype="<u8"))
        rows, cols = self.device_shape()
        slots = cols - 2
        with self._lock:
            # the used flags are the source of truth; the occupancy
            # control word is derived and recomputed here.  The LIVE dict
            # is mutated in place — the host-JIT fast path binds
            # ``self._table.get`` at compile time (dict identity is part
            # of the map's contract) and ``lookup_ref`` hands out value
            # bytearrays, so both must survive a device writeback.
            fresh = set()
            for i in range(self.max_entries):
                if int(a[i, slots + 1]) != 0:
                    kb = int(a[i, slots]).to_bytes(self.key_size, "little")
                    fresh.add(kb)
                    slot = self._table.get(kb)
                    if slot is None:
                        self._table[kb] = bytearray(a[i, :slots].tobytes())
                    else:
                        slot[:] = a[i, :slots].tobytes()
            for kb in [k for k in self._table if k not in fresh]:
                del self._table[kb]
            self._version += 1


class PerCpuArrayMap(ArrayMap):
    """Per-thread-slot array; reads aggregate by sum (counter idiom)."""

    kind = "percpu_array"
    N_SLOTS = 8

    def __init__(self, name: str, value_size: int, max_entries: int):
        super().__init__(name, value_size, max_entries)
        self._cpu_slots = [
            [bytearray(value_size) for _ in range(max_entries)]
            for _ in range(self.N_SLOTS)
        ]
        self._tls = threading.local()

    def _cpu(self) -> int:
        cpu = getattr(self._tls, "cpu", None)
        if cpu is None:
            cpu = threading.get_ident() % self.N_SLOTS
            self._tls.cpu = cpu
        return cpu

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        idx = self._index(key)
        return None if idx is None else self._cpu_slots[self._cpu()][idx]

    def native_view(self) -> "NativeMapView":
        # slot selection is thread-dependent: no stable address table
        raise MapError(
            f"map {self.name}: percpu_array has no native view")

    def aggregate_u64(self, key: int, slot: int = 0) -> int:
        idx = struct.unpack("<I", struct.pack("<I", key))[0]
        if idx >= self.max_entries:
            raise MapError(f"{self.name}: key {key} out of range")
        total = 0
        for cpu in range(self.N_SLOTS):
            total += struct.unpack_from("<Q", self._cpu_slots[cpu][idx], slot * 8)[0]
        return total & U64


class PerDeviceArrayMap(ArrayMap):
    """One ArrayMap shard per device index, host merge view.

    The host selects which shard the execution tiers (and the in-graph
    device protocol) address via :meth:`set_device`; ``aggregate_u64``
    merges by sum (the counter/histogram idiom), ``device_u64`` reads
    one shard.  Because the device protocol exposes exactly the current
    shard, the in-graph lowering is the plain array lowering."""

    kind = "perdev_array"
    N_DEVICES = 8

    def __init__(self, name: str, value_size: int, max_entries: int):
        super().__init__(name, value_size, max_entries)
        self._dev_slots = [self._slots] + [
            [bytearray(value_size) for _ in range(max_entries)]
            for _ in range(self.N_DEVICES - 1)
        ]
        self._current = 0

    @property
    def current_device(self) -> int:
        return self._current

    def set_device(self, dev: int) -> None:
        """Select the shard subsequent lookups/stores (and device
        uploads) address.  Counts as a content mutation: the in-graph
        bridge must re-upload after a shard switch."""
        with self._lock:
            self._current = dev % self.N_DEVICES
            self._version += 1

    def _live_slots(self) -> List[bytearray]:
        return self._dev_slots[self._current]

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        idx = self._index(key)
        return None if idx is None else self._live_slots()[idx]

    def update(self, key: bytes, value: bytes) -> int:
        self._check_value(value)
        idx = self._index(key)
        if idx is None:
            return -1
        with self._lock:
            self._live_slots()[idx][:] = value
            self._version += 1
        return 0

    def device_u64(self, dev: int, key: int, slot: int = 0) -> int:
        if key >= self.max_entries:
            raise MapError(f"{self.name}: key {key} out of range")
        return struct.unpack_from(
            "<Q", self._dev_slots[dev % self.N_DEVICES][key], slot * 8)[0]

    def aggregate_u64(self, key: int, slot: int = 0) -> int:
        """Host merge view: sum of one u64 slot across every shard."""
        if key >= self.max_entries:
            raise MapError(f"{self.name}: key {key} out of range")
        total = 0
        for shard in self._dev_slots:
            total += struct.unpack_from("<Q", shard[key], slot * 8)[0]
        return total & U64


class RingBufMap(BpfMap):
    """Bounded MPSC event stream — the BPF_MAP_TYPE_RINGBUF analogue.

    Producers (policy programs via the ``ringbuf_reserve`` /
    ``ringbuf_submit`` / ``ringbuf_discard`` helpers, or host code via
    :meth:`output`) append fixed-size records; consumers :meth:`drain`
    them FIFO.  State machine (identical on every tier — vm.py is the
    differential ground truth, the in-graph tiers run the same logic on
    the control words appended to the device array):

      * cursors ``head``/``tail`` are free-running u64s; live records
        occupy rows ``tail..head-1`` modulo ``max_entries``;
      * ``reserve`` first implicitly commits any still-pending
        reservation (a policy that forgot to submit cannot poison the
        ring), then fails with NULL — counting one drop — when the ring
        is full, else marks the row at ``head % max_entries`` pending
        and returns it WITHOUT zeroing;
      * ``submit`` publishes the pending record (``head += 1``);
        ``discard`` abandons it (the row is reused by the next reserve);
      * drop-on-full is the program-facing rule on every tier; the
        host-only :meth:`output` producer can instead run in
        ``overwrite`` mode, dropping the OLDEST record (decision-log /
        printk semantics), which still counts into ``drops``.
    """

    kind = "ringbuf"

    def __init__(self, name: str, value_size: int, max_entries: int,
                 *, overwrite: bool = False):
        if value_size % 8 != 0:
            raise MapError(f"ringbuf {name}: record size {value_size} "
                           "must be a multiple of 8")
        super().__init__(name, 4, value_size, max_entries)
        self._rows = [bytearray(value_size) for _ in range(max_entries)]
        self._head = 0
        self._tail = 0
        self._drops = 0
        self._pending = False
        self.overwrite = overwrite

    # -- program-facing helper surface (called by the execution tiers) -----
    def reserve_ref(self) -> Optional[bytearray]:
        with self._lock:
            if self._pending:
                self._head += 1
                self._pending = False
            if self._head - self._tail >= self.max_entries:
                self._drops += 1
                self._version += 1
                return None
            self._pending = True
            self._version += 1
            return self._rows[self._head % self.max_entries]

    def submit(self) -> int:
        with self._lock:
            if self._pending:
                self._head += 1
                self._pending = False
            self._version += 1
        return 0

    def discard(self) -> int:
        with self._lock:
            self._pending = False
            self._version += 1
        return 0

    # -- host producer/consumer surface ------------------------------------
    def output(self, data: bytes) -> int:
        """Host-side reserve+write+submit of one full record; in
        ``overwrite`` mode a full ring evicts the oldest record (counted
        as a drop) instead of rejecting the new one."""
        data = bytes(data)
        self._check_value(data)
        with self._lock:
            if self._pending:
                self._head += 1
                self._pending = False
            if self._head - self._tail >= self.max_entries:
                self._drops += 1
                if not self.overwrite:
                    self._version += 1
                    return -1
                self._tail += 1
            self._rows[self._head % self.max_entries][:] = data
            self._head += 1
            self._version += 1
        return 0

    def drain(self, max_records: Optional[int] = None) -> List[bytes]:
        """Consume up to ``max_records`` records, oldest first."""
        with self._lock:
            n = self._head - self._tail
            if max_records is not None:
                n = min(n, max_records)
            out = [bytes(self._rows[(self._tail + i) % self.max_entries])
                   for i in range(n)]
            if n:
                self._tail += n
                self._version += 1
            return out

    def peek(self) -> List[bytes]:
        """Non-destructive copy of every live record, oldest first."""
        with self._lock:
            return [bytes(self._rows[(self._tail + i) % self.max_entries])
                    for i in range(self._head - self._tail)]

    def record(self, i: int) -> bytes:
        """Random access into the live window (negative = from newest)."""
        with self._lock:
            n = self._head - self._tail
            if i < 0:
                i += n
            if not 0 <= i < n:
                raise IndexError(f"ringbuf {self.name}: index out of range")
            return bytes(self._rows[(self._tail + i) % self.max_entries])

    def clear(self) -> None:
        """Discard every live record (drop counters are cumulative and
        survive a clear)."""
        with self._lock:
            self._tail = self._head
            self._pending = False
            self._version += 1

    def __len__(self) -> int:
        with self._lock:
            return self._head - self._tail

    @property
    def head(self) -> int:
        return self._head

    @property
    def tail(self) -> int:
        return self._tail

    @property
    def drops(self) -> int:
        return self._drops

    # -- keyed surface: a ringbuf has none ---------------------------------
    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        raise MapError(f"ringbuf {self.name} has no keyed lookup; "
                       "use reserve/submit and drain()")

    def update(self, key: bytes, value: bytes) -> int:
        raise MapError(f"ringbuf {self.name} has no keyed update; "
                       "use output()")

    def delete(self, key: bytes) -> int:
        raise MapError(f"ringbuf {self.name} has no keyed delete")

    def keys(self) -> Iterator[bytes]:
        return iter(())

    # -- in-graph device protocol ------------------------------------------
    def _ctl_pos(self, w: int) -> tuple:
        slots = self.value_size // 8
        return (self.max_entries + w // slots, w % slots)

    def to_device(self) -> np.ndarray:
        rows, slots = self.device_shape()
        with self._lock:
            flat = b"".join(bytes(r) for r in self._rows)
            arr = np.zeros((rows, slots), dtype="<u8")
            arr[:self.max_entries] = np.frombuffer(flat, dtype="<u8").reshape(
                self.max_entries, slots)
            for w, v in enumerate((self._head, self._tail, self._drops,
                                   1 if self._pending else 0)):
                arr[self._ctl_pos(w)] = v
        return arr

    def from_device(self, arr) -> None:
        a = np.ascontiguousarray(np.asarray(arr, dtype="<u8"))
        vs = self.value_size
        data = a[:self.max_entries].tobytes()
        with self._lock:
            for i, r in enumerate(self._rows):
                r[:] = data[i * vs:(i + 1) * vs]
            self._head = int(a[self._ctl_pos(0)])
            # the device never consumes: its tail is the tail it was
            # uploaded with.  The host may have drained since — keep the
            # larger cursor so a host drain between upload and writeback
            # is never un-consumed (clamped to head for safety).
            self._tail = min(max(self._tail, int(a[self._ctl_pos(1)])),
                             self._head)
            self._drops = int(a[self._ctl_pos(2)])
            self._pending = bool(int(a[self._ctl_pos(3)]))
            self._version += 1


class LruHashMap(BpfMap):
    """Fixed-capacity hash with clock/LRU eviction (BPF_MAP_TYPE_LRU_HASH).

    Storage is the device layout run on the host — ``max_entries`` rows
    of ``[value, key, recency]`` plus a global clock — so every tier
    executes the identical state machine and differential tests compare
    bit-identical state:

      * lookup scans for ``key`` among occupied rows (``recency > 0``);
        a hit refreshes ``recency = ++clock`` (lookup MUTATES the map);
      * update overwrites a hit in place, else claims the row with the
        smallest recency — free rows have recency 0, so they win before
        any occupied row, and ties break to the lowest index;
      * delete frees the row (``recency = 0``); eviction means update
        never fails for capacity.

    Keys are the little-endian integer value of the declared key bytes
    (key_size <= 8, so a key fits one u64 device cell)."""

    kind = "lru_hash"

    def __init__(self, name: str, key_size: int, value_size: int,
                 max_entries: int):
        if key_size not in (4, 8):
            raise MapError(f"lru_hash {name}: key size must be 4 or 8")
        super().__init__(name, key_size, value_size, max_entries)
        self._key_ints = [0] * max_entries
        self._vals = [bytearray(value_size) for _ in range(max_entries)]
        self._rec = [0] * max_entries
        self._clock = 0
        # host acceleration only: key -> occupied row, so the hot lookup
        # path is O(1) instead of a row scan.  The row arrays above stay
        # the source of truth (they ARE the device layout); the index is
        # rebuilt wholesale on from_device()
        self._index: Dict[int, int] = {}

    def _kint(self, key: bytes) -> int:
        self._check_key(key)
        return int.from_bytes(bytes(key), "little")

    def _find(self, k: int) -> Optional[int]:
        return self._index.get(k)

    def lookup_ref(self, key: bytes) -> Optional[bytearray]:
        k = self._kint(key)
        with self._lock:
            i = self._find(k)
            if i is None:
                return None
            self._clock += 1
            self._rec[i] = self._clock
            self._version += 1
            return self._vals[i]

    def peek_ref(self, key: bytes) -> Optional[bytearray]:
        """Lookup WITHOUT refreshing recency — host introspection that
        must not perturb eviction order (snapshots, exporters)."""
        k = self._kint(key)
        with self._lock:
            i = self._find(k)
            return None if i is None else self._vals[i]

    def update(self, key: bytes, value: bytes) -> int:
        k = self._kint(key)
        self._check_value(value)
        with self._lock:
            i = self._find(k)
            if i is None:
                # victim: smallest recency, lowest index on ties — free
                # rows (recency 0) always win before any occupied row
                i = min(range(self.max_entries), key=lambda j: self._rec[j])
                if self._rec[i] > 0:
                    self._index.pop(self._key_ints[i], None)
                self._index[k] = i
            self._key_ints[i] = k
            self._vals[i][:] = value
            self._clock += 1
            self._rec[i] = self._clock
            self._version += 1
        return 0

    def delete(self, key: bytes) -> int:
        k = self._kint(key)
        with self._lock:
            i = self._find(k)
            if i is None:
                return -1
            self._index.pop(k, None)
            self._rec[i] = 0
            self._key_ints[i] = 0
            self._vals[i][:] = bytes(self.value_size)
            self._version += 1
            return 0

    def keys(self) -> Iterator[bytes]:
        with self._lock:
            out = [self._key_ints[i].to_bytes(self.key_size, "little")
                   for i in range(self.max_entries) if self._rec[i] > 0]
        return iter(out)

    def snapshot(self) -> Dict[bytes, bytes]:
        # bypass lookup_ref: a snapshot must not refresh recency
        with self._lock:
            return {self._key_ints[i].to_bytes(self.key_size, "little"):
                    bytes(self._vals[i])
                    for i in range(self.max_entries) if self._rec[i] > 0}

    def __len__(self) -> int:
        with self._lock:
            return sum(1 for r in self._rec if r > 0)

    # -- in-graph device protocol ------------------------------------------
    def to_device(self) -> np.ndarray:
        rows, cols = self.device_shape()
        slots = self.value_size // 8
        with self._lock:
            arr = np.zeros((rows, cols), dtype="<u8")
            for i in range(self.max_entries):
                arr[i, :slots] = np.frombuffer(bytes(self._vals[i]),
                                               dtype="<u8")
                arr[i, slots] = self._key_ints[i]
                arr[i, slots + 1] = self._rec[i]
            arr[self.max_entries, 0] = self._clock
        return arr

    def from_device(self, arr) -> None:
        a = np.ascontiguousarray(np.asarray(arr, dtype="<u8"))
        slots = self.value_size // 8
        with self._lock:
            for i in range(self.max_entries):
                self._vals[i][:] = a[i, :slots].tobytes()
                self._key_ints[i] = int(a[i, slots])
                self._rec[i] = int(a[i, slots + 1])
            self._clock = int(a[self.max_entries, 0])
            self._index = {self._key_ints[i]: i
                           for i in range(self.max_entries)
                           if self._rec[i] > 0}
            self._version += 1


class RingView:
    """Deque-like decoded view over a host-producer :class:`RingBufMap`.

    The dogfooding adapter: the dispatcher's decision log keeps its
    familiar ``decisions[-1]`` / ``len`` / ``clear`` surface while the
    storage is the observability plane's ring (overwrite mode: a full
    ring evicts the oldest record, like the deque it replaced).
    ``maxlen`` echoes the configured bound (including 0 = log nothing),
    and indexing decodes single records in O(1)."""

    def __init__(self, capacity: Optional[int], record_size: int,
                 encode, decode, *, name: str = "ring_view"):
        # capacity None is the legacy "unbounded" spelling; the ring is
        # the bound now, so it maps to the historical default
        self.maxlen = capacity
        cap = 4096 if capacity is None else max(int(capacity), 0)
        self._enabled = cap > 0
        self.ring = RingBufMap(name, record_size, max(cap, 1),
                               overwrite=True)
        self._enc = encode
        self._dec = decode

    def append(self, item) -> None:
        if self._enabled:
            self.ring.output(self._enc(item))

    def clear(self) -> None:
        self.ring.clear()

    def __len__(self) -> int:
        return len(self.ring) if self._enabled else 0

    def __bool__(self) -> bool:
        return len(self) > 0

    def __iter__(self):
        return iter(self._dec(r) for r in self.ring.peek())

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._dec(r) for r in self.ring.peek()[i]]
        return self._dec(self.ring.record(i))

    @property
    def drops(self) -> int:
        return self.ring.drops


class NativeMapView:
    """Stable C-ABI view of array-family map storage for the native tier.

    One contiguous **slot directory** per shard — a ``u64[max_entries]``
    ctypes table holding the base address of every live slot bytearray.
    Exporting each slot via the buffer protocol pins its backing memory
    for the map's lifetime (a pinned bytearray cannot be resized, and
    nothing on the structured surface resizes slots — ``update()`` /
    ``from_device()`` are same-length slice assignments), so the
    addresses the directory hands to compiled code stay valid while
    Python-side tiers keep reading and writing the *same* bytes.  That
    makes native and host mutations mutually visible with no copying in
    either direction, preserving the VM's per-slot concurrency model.

    The view is refused for ``value_size < 8`` maps: the VM's
    ``ema_update`` can *grow* such slots by slice-assigning 8 bytes, and
    pinning would turn that grow into a ``BufferError`` for every tier
    sharing the map.  Version tracking: the native tier's exit path
    increments the map's ``_native_bumps`` cell (one machine add, summed
    into :attr:`BpfMap.version`), so DeviceBridge caches re-upload
    exactly as they do for the VM/JIT tiers.
    """

    def __init__(self, m: BpfMap):
        if m.kind not in ("array", "perdev_array"):
            raise MapError(
                f"map {m.name}: native view requires an array-family map")
        if m.value_size < 8:
            raise MapError(
                f"map {m.name}: native view requires value_size >= 8 "
                "(sub-8-byte slots can be grown by ema_update)")
        self.map = m
        with m.lock:
            shards = m._dev_slots if isinstance(m, PerDeviceArrayMap) \
                else [m._slots]
            # exports pin slot buffers (block resize) and keep them alive
            self._exports = [
                [(ctypes.c_ubyte * len(s)).from_buffer(s) for s in shard]
                for shard in shards]
            self._dirs = [
                (ctypes.c_uint64 * len(exps))(
                    *[ctypes.addressof(e) for e in exps])
                for exps in self._exports]
            self.dir_addrs = tuple(ctypes.addressof(d) for d in self._dirs)

    def dir_addr(self, shard: int = 0) -> int:
        """Address of the slot directory for ``shard``."""
        return self.dir_addrs[shard]

    def slot_addr(self, idx: int, shard: Optional[int] = None) -> int:
        """Address of slot ``idx``'s value bytes (current shard default)."""
        if shard is None:
            shard = self.map._current \
                if isinstance(self.map, PerDeviceArrayMap) else 0
        return self._dirs[shard][idx]


MAP_KINDS = {
    "array": ArrayMap,
    "hash": HashMap,
    "percpu_array": PerCpuArrayMap,
    "perdev_array": PerDeviceArrayMap,
    "ringbuf": RingBufMap,
    "lru_hash": LruHashMap,
}


class MapRegistry:
    """Named maps shared across programs — the composability namespace.

    Two tiers of sharing:

    * every created map is reachable by name through :meth:`get` while the
      registry lives — incidental sharing within one runtime;
    * **pinned** maps (:meth:`pin` / :meth:`get_pinned`) form an explicit
      namespace, the bpffs-pin analogue: a profiler program declares its
      EMA map ``shared=True`` and a tuner program (or host-side tooling)
      finds the same object by name, without ever holding a program
      reference.  Pinned maps survive every program detach/replace.
    """

    def __init__(self):
        self._maps: Dict[str, BpfMap] = {}
        self._pinned: Dict[str, BpfMap] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _shape_of(kind: str, key_size: int, value_size: int,
                  max_entries: int) -> tuple:
        # array-family (and keyless) maps force u32 keys regardless of
        # the declaration; only the hash family keeps declared keys
        return (kind,
                key_size if kind in ("hash", "lru_hash") else 4,
                value_size, max_entries)

    def validate(self, name: str, kind: str, *, key_size: int = 4,
                 value_size: int = 8, max_entries: int = 64) -> None:
        """Shape-check a declaration against the registry WITHOUT creating
        anything — the dry-run half of a transactional bundle load."""
        if kind not in MAP_KINDS:
            raise MapError(f"unknown map kind {kind!r}")
        with self._lock:
            m = self._maps.get(name)
            if m is not None and (m.kind, m.key_size, m.value_size,
                                  m.max_entries) != self._shape_of(
                                      kind, key_size, value_size, max_entries):
                raise MapError(f"map {name}: redefinition with different shape")

    def create(self, name: str, kind: str, *, key_size: int = 4,
               value_size: int = 8, max_entries: int = 64) -> BpfMap:
        with self._lock:
            if name in self._maps:
                m = self._maps[name]
                if (m.kind, m.key_size, m.value_size, m.max_entries) != \
                        self._shape_of(kind, key_size, value_size, max_entries):
                    raise MapError(f"map {name}: redefinition with different shape")
                return m
            if kind in ("hash", "lru_hash"):
                m = MAP_KINDS[kind](name, key_size, value_size, max_entries)
            elif kind in ("array", "percpu_array", "perdev_array",
                          "ringbuf"):
                m = MAP_KINDS[kind](name, value_size, max_entries)
            else:
                raise MapError(f"unknown map kind {kind!r}")
            self._maps[name] = m
            return m

    def get(self, name: str) -> BpfMap:
        try:
            return self._maps[name]
        except KeyError:
            raise MapError(f"map {name!r} not found") from None

    # ---- pinned namespace (cross-plugin maps, the bpffs-pin analogue) ----
    def pin(self, name: str) -> BpfMap:
        """Pin an existing map into the shared namespace (idempotent)."""
        with self._lock:
            try:
                m = self._maps[name]
            except KeyError:
                raise MapError(
                    f"cannot pin {name!r}: map not found") from None
            self._pinned[name] = m
            return m

    def get_pinned(self, name: str) -> BpfMap:
        try:
            return self._pinned[name]
        except KeyError:
            raise MapError(
                f"map {name!r} is not pinned; pinned maps: "
                f"{sorted(self._pinned) or 'none'}") from None

    def unpin(self, name: str) -> None:
        with self._lock:
            if self._pinned.pop(name, None) is None:
                raise MapError(f"map {name!r} is not pinned")

    def is_pinned(self, name: str) -> bool:
        return name in self._pinned

    def pinned_names(self):
        return sorted(self._pinned)

    def __contains__(self, name: str) -> bool:
        return name in self._maps

    def names(self):
        return list(self._maps)
