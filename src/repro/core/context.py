"""Typed context structs passed to policy programs (the r1 argument).

Mirrors NCCLbpf's ``policy_context`` / ``profiler_context``: fixed-layout
structs with *input* (read-only) and *output* (read-write) fields.  The
verifier enforces field permissions and bounds; writing an input field is
one of the paper's seven rejected bug classes.

All fields are 8-byte slots (u64) for simplicity of layout; the frontends
expose them by name.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    offset: int
    size: int
    writable: bool


class CtxType:
    def __init__(self, name: str, fields: List[Tuple[str, bool]]):
        self.name = name
        self.fields: Dict[str, Field] = {}
        off = 0
        for fname, writable in fields:
            self.fields[fname] = Field(fname, off, 8, writable)
            off += 8
        self.size = off

    def field_at(self, offset: int, size: int) -> Field:
        """Return the field covering [offset, offset+size) or raise."""
        for f in self.fields.values():
            if f.offset == offset and size <= f.size:
                return f
        raise KeyError(f"{self.name}: no field at offset {offset} size {size}")

    def offset_of(self, name: str) -> int:
        return self.fields[name].offset

    def __repr__(self) -> str:
        return f"CtxType({self.name}, {len(self.fields)} fields, {self.size}B)"


# --- Tuner: the getCollInfo analogue -------------------------------------
# Inputs describe the collective call; outputs are the policy's decision.
# algorithm/protocol/n_channels mirror NCCL tuner v3; the cost_table
# translation happens in the dispatch layer (tuner v5 style).
POLICY_CONTEXT = CtxType(
    "policy_context",
    [
        # inputs (read-only)
        ("coll_type", False),     # CollType enum value
        ("msg_size", False),      # bytes
        ("n_ranks", False),       # devices participating
        ("comm_id", False),       # stable communicator hash
        ("axis_kind", False),     # AxisKind enum (data/model/pod/expert)
        ("dtype_bytes", False),   # element size of the operand
        ("max_channels", False),  # clamp supplied by the framework
        ("topo_links", False),    # ICI links per chip on this axis
        # outputs (read-write)
        ("algorithm", True),
        ("protocol", True),
        ("n_channels", True),
        # topology inputs (read-only) — appended AFTER the outputs so
        # every pre-existing field keeps its offset (compiled programs
        # bake offsets in).  Fed from launch/mesh.py::mesh_topology via
        # CollectiveDispatcher.set_topology; both default to 0 = unknown
        # (policies treat 0 ranks_per_node as "all ranks on one node").
        ("n_nodes", False),        # distinct hosts/processes in the mesh
        ("ranks_per_node", False),  # ranks co-located per host
    ],
)

# --- Profiler: event callback analogue ------------------------------------
PROFILER_CONTEXT = CtxType(
    "profiler_context",
    [
        ("event_type", False),    # ProfEvent enum
        ("coll_type", False),
        ("msg_size", False),
        ("comm_id", False),
        ("latency_ns", False),
        ("n_channels", False),
        ("algorithm", False),
        ("timestamp_ns", False),
    ],
)

# --- Net: per-issue data-plane hook ---------------------------------------
NET_CONTEXT = CtxType(
    "net_context",
    [
        ("op", False),            # 0=isend 1=irecv
        ("bytes", False),
        ("peer", False),
        ("comm_id", False),
        ("conn_id", False),
    ],
)

# --- Env: init-time runtime-parameter hook (NCCL env plugin) ---------------
ENV_CONTEXT = CtxType(
    "env_context",
    [
        # inputs: deployment topology
        ("n_devices", False),
        ("tp", False),
        ("dp", False),
        ("n_pods", False),
        ("topo_links", False),
        # outputs: framework defaults (0 = keep built-in)
        ("default_algorithm", True),
        ("default_protocol", True),
        ("default_channels", True),
        ("max_channels", True),
    ],
)

CTX_TYPES = {
    "tuner": POLICY_CONTEXT,
    "profiler": PROFILER_CONTEXT,
    "net": NET_CONTEXT,
    "env": ENV_CONTEXT,
}


# --- Enums shared with the collectives layer -------------------------------

class CollType:
    ALL_REDUCE = 0
    ALL_GATHER = 1
    REDUCE_SCATTER = 2
    ALL_TO_ALL = 3
    BROADCAST = 4
    PPERMUTE = 5

    NAMES = {0: "all_reduce", 1: "all_gather", 2: "reduce_scatter",
             3: "all_to_all", 4: "broadcast", 5: "ppermute"}


class Algo:
    DEFAULT = 0   # XLA-native lowering (psum / all_to_all) — the NVLS analogue
    RING = 1
    TREE = 2      # recursive halving/doubling
    BIDIR_RING = 3

    NAMES = {0: "default", 1: "ring", 2: "tree", 3: "bidir_ring"}
    COUNT = 4


class Proto:
    SIMPLE = 0    # f32 wire, bandwidth-optimal
    LL = 1        # bf16 wire (latency-optimized analogue)
    LL128 = 2     # bf16 wire, f32 accumulation

    NAMES = {0: "simple", 1: "ll", 2: "ll128"}
    COUNT = 3


class AxisKind:
    DATA = 0
    MODEL = 1
    POD = 2
    EXPERT = 3

    NAMES = {0: "data", 1: "model", 2: "pod", 3: "expert"}


class ProfEvent:
    COLL_BEGIN = 0
    COLL_END = 1
    STEP_END = 2


class PolicyContextValues:
    """Concrete runtime value for POLICY_CONTEXT, backed by a bytearray."""

    __slots__ = ("buf", "ctx_type")

    def __init__(self, ctx_type: CtxType = POLICY_CONTEXT, **kwargs):
        self.ctx_type = ctx_type
        self.buf = bytearray(ctx_type.size)
        for k, v in kwargs.items():
            self[k] = v

    def __getitem__(self, name: str) -> int:
        f = self.ctx_type.fields[name]
        return int.from_bytes(self.buf[f.offset:f.offset + 8], "little", signed=False)

    def __setitem__(self, name: str, value: int) -> None:
        f = self.ctx_type.fields[name]
        self.buf[f.offset:f.offset + 8] = (int(value) & ((1 << 64) - 1)).to_bytes(8, "little")

    def as_dict(self) -> dict:
        return {k: self[k] for k in self.ctx_type.fields}


def make_ctx(kind: str, **kwargs) -> PolicyContextValues:
    return PolicyContextValues(CTX_TYPES[kind], **kwargs)
