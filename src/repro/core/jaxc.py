"""jaxc — verified policy bytecode compiled to pure JAX (the in-graph tier).

This tier goes beyond the paper: NCCLbpf's policies execute on the host
around each collective launch; on TPU the step function is one fused XLA
program, so we *if-convert* the verified policy into jnp ops and run it
INSIDE the compiled program.  Closed-loop adaptation (profiler map ->
tuner decision -> ``lax.switch`` branch) then happens per step with zero
host round-trips and zero retraces.

Why verification makes this possible:
  * the CFG is a forward-only DAG  -> classic if-conversion: execute every
    instruction under a predicate, writes select via ``jnp.where``
  * every memory insn has a statically known region (ctx / stack / one
    specific map)  -> each load/store lowers to a typed gather/scatter
  * bounded stack, no unbounded loops -> fixed-size traced state

Supported surface (JaxcError otherwise): ALU64/32, jumps, ctx loads/stores
(8-byte fields), stack loads/stores (static or dynamic offset), ARRAY maps
(u64-slot granularity), helpers map_lookup_elem / map_update_elem /
ema_update.  Hash maps and wall-clock helpers are host-tier-only.

State threading: the compiled function has signature

    fn(ctx: uint32[n_fields*2] as u64 pairs? NO — see below]

We pass ctx and maps as uint64 arrays under ``jax.enable_x64(True)``
(scoped to the policy body; the surrounding model code stays 32-bit).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import helpers as H
from .isa import (FP_REG, Insn, STACK_SIZE, alu_base, alu_width, is_alu,
                  is_imm_form, is_jump_cond, is_load, is_store, jump_base,
                  mem_size)
from .maps import ArrayMap, BpfMap
from .program import Program
from .verifier import verify_with_info

M64 = (1 << 64) - 1


class JaxcError(Exception):
    pass


# pointer encoding (mirrors the host JIT):
#   stack: 1<<32 | byte_off
#   ctx:   2<<32 | byte_off
#   map value (array map mi): (16+mi)<<40 | key<<8 ... key fits 32 bits?
# we need key (u32) and offset; use: (16+mi)<<56 | key<<24 | byte_off
# (byte_off < 2^24, key < 2^32 truncated to 2^32... keep key<=2^31)
_STACK_TAG = 1 << 32
_CTX_TAG = 2 << 32


def _map_tag(mi: int):
    return (16 + mi) << 56


def check_supported(prog: Program) -> None:
    for d in prog.maps:
        if d.kind != "array":
            raise JaxcError(
                f"map '{d.name}' is {d.kind}; in-graph tier supports array "
                "maps only (hash maps live on the host tier)")
        if d.value_size % 8:
            raise JaxcError(f"map '{d.name}': value_size must be 8-aligned")
    for pc, insn in enumerate(prog.insns):
        if insn.op == "call" and insn.imm not in (1, 2, 64):
            raise JaxcError(
                f"helper {H.HELPERS[insn.imm].name} (insn {pc}) is not "
                "available in-graph")


def compile_jax(prog: Program):
    """Return (fn, map_names).

    ``fn(ctx_vec, map_arrays) -> (ret, ctx_vec_out, map_arrays_out)`` where
    ``ctx_vec`` is uint64[n_fields] and ``map_arrays`` is a dict
    name -> uint64[max_entries, value_slots].  Pure; jit/vmap/scan-safe.
    """
    check_supported(prog)
    vinfo = verify_with_info(prog)
    insns = prog.insns
    decls = list(prog.maps)
    map_index = {d.name: i for i, d in enumerate(decls)}
    n_fields = prog.ctx_type.size // 8

    def u64(x):
        return jnp.asarray(x, jnp.uint64)

    def run(ctx_vec, map_arrays: Dict[str, jnp.ndarray]):
        with jax.enable_x64(True):
            ctx = jnp.asarray(ctx_vec, jnp.uint64)
            maps = {k: jnp.asarray(v, jnp.uint64) for k, v in map_arrays.items()}
            regs: List[jnp.ndarray] = [u64(0)] * 11
            regs[1] = u64(_CTX_TAG)
            regs[FP_REG] = u64(_STACK_TAG | STACK_SIZE)
            stack = jnp.zeros(STACK_SIZE // 8, jnp.uint64)  # u64 slots

            true_ = jnp.asarray(True)
            false_ = jnp.asarray(False)
            # incoming predicates per pc
            incoming: Dict[int, List[jnp.ndarray]] = {0: [true_]}
            ret = u64(0)
            done = false_

            def pred_or(ps):
                p = ps[0]
                for q in ps[1:]:
                    p = jnp.logical_or(p, q)
                return p

            def sel(p, new, old):
                return jnp.where(p, new, old)

            def wreg(p, idx, val):
                regs[idx] = sel(p, jnp.asarray(val, jnp.uint64), regs[idx])

            def stack_load(ptr, size):
                # u64-slot stack: require 8-aligned 8-byte access for dynamic
                slot = ((ptr & jnp.uint64(0xFFFFFFFF)) >> 3).astype(jnp.int32)
                word = stack[slot]
                if size == 8:
                    return word
                sh = ((ptr & jnp.uint64(7)) * 8).astype(jnp.uint64)
                mask = jnp.uint64((1 << (8 * size)) - 1)
                return (word >> sh) & mask

            def stack_store(p, ptr, size, val):
                nonlocal stack
                off = ptr & jnp.uint64(0xFFFFFFFF)
                slot = (off >> 3).astype(jnp.int32)
                word = stack[slot]
                if size == 8:
                    new = jnp.asarray(val, jnp.uint64)
                else:
                    sh = ((off & jnp.uint64(7)) * 8).astype(jnp.uint64)
                    mask = jnp.uint64((1 << (8 * size)) - 1)
                    new = (word & ~(mask << sh)) | ((jnp.asarray(val, jnp.uint64) & mask) << sh)
                stack = stack.at[slot].set(sel(p, new, word))

            def mapval_decode(ptr):
                mi = ((ptr >> jnp.uint64(56)) - 16).astype(jnp.int32)
                key = ((ptr >> jnp.uint64(24)) & jnp.uint64(0xFFFFFFFF)).astype(jnp.int32)
                off = (ptr & jnp.uint64(0xFFFFFF))
                return mi, key, off

            for pc, insn in enumerate(insns):
                ps = incoming.get(pc)
                if ps is None:
                    continue  # statically unreachable
                P = pred_or(ps)
                op = insn.op

                def flow_to(tgt, p):
                    incoming.setdefault(tgt, []).append(p)

                if op == "exit":
                    take = jnp.logical_and(P, jnp.logical_not(done))
                    ret = sel(take, regs[0], ret)
                    done = jnp.logical_or(done, P)
                    continue
                if op == "ja":
                    flow_to(pc + 1 + insn.off, P)
                    continue
                if op == "lddw":
                    wreg(P, insn.dst, jnp.uint64(insn.imm & M64))
                    flow_to(pc + 1, P)
                    continue
                if op == "ldmap":
                    mi = map_index[insn.map_name]
                    wreg(P, insn.dst, jnp.uint64(_map_tag(mi)))
                    flow_to(pc + 1, P)
                    continue
                if op == "call":
                    self_ret = self_call(pc, insn, P, regs, stack_load,
                                         maps, decls)
                    wreg(P, 0, self_ret)
                    for r in (1, 2, 3, 4, 5):
                        wreg(P, r, jnp.uint64(0))
                    flow_to(pc + 1, P)
                    continue
                if is_alu(op):
                    width = alu_width(op)
                    base = alu_base(op)
                    a = regs[insn.dst]
                    b = jnp.uint64(insn.imm & M64) if is_imm_form(op) \
                        else regs[insn.src]
                    wreg(P, insn.dst, _alu_jax(base, width, a, b))
                    flow_to(pc + 1, P)
                    continue
                if is_jump_cond(op):
                    base = jump_base(op)
                    a = regs[insn.dst]
                    b = jnp.uint64(insn.imm & M64) if is_imm_form(op) \
                        else regs[insn.src]
                    c = _cmp_jax(base, a, b)
                    flow_to(pc + 1 + insn.off, jnp.logical_and(P, c))
                    flow_to(pc + 1, jnp.logical_and(P, jnp.logical_not(c)))
                    continue
                if is_load(op):
                    size = mem_size(op)
                    region, mname, base = vinfo.mem_info[pc]
                    ptr = regs[insn.src] + jnp.uint64(insn.off & M64)
                    if region == "ctx":
                        off = base + insn.off  # static (verified)
                        val = ctx[off // 8]
                        if size < 8:
                            val = val & jnp.uint64((1 << (8 * size)) - 1)
                    elif region == "stack":
                        val = stack_load(ptr, size)
                    else:  # mapval
                        mi, key, off = mapval_decode(ptr)
                        slot = (off >> jnp.uint64(3)).astype(jnp.int32)
                        val = maps[mname][key, slot]
                        if size < 8:
                            val = val & jnp.uint64((1 << (8 * size)) - 1)
                    wreg(P, insn.dst, val)
                    flow_to(pc + 1, P)
                    continue
                if is_store(op):
                    size = mem_size(op)
                    region, mname, base = vinfo.mem_info[pc]
                    val = jnp.uint64(insn.imm & M64) if not op.startswith("stx") \
                        else regs[insn.src]
                    ptr = regs[insn.dst] + jnp.uint64(insn.off & M64)
                    if region == "ctx":
                        slot = (base + insn.off) // 8
                        ctx = ctx.at[slot].set(sel(P, val, ctx[slot]))
                    elif region == "stack":
                        stack_store(P, ptr, size, val)
                    else:  # mapval
                        mi, key, off = mapval_decode(ptr)
                        slot = (off >> jnp.uint64(3)).astype(jnp.int32)
                        old = maps[mname][key, slot]
                        maps[mname] = maps[mname].at[key, slot].set(
                            sel(P, val, old))
                    flow_to(pc + 1, P)
                    continue
                raise JaxcError(f"unhandled op {op}")

            ret32 = ret
            return ret32, ctx, maps

    def self_call(pc: int, insn: Insn, P, regs, stack_load, maps, decls):
        hid = insn.imm
        # the verifier proved exactly which map reaches this call site
        mname = vinfo.call_map[pc]
        if mname is None:
            raise JaxcError(f"helper at insn {pc} has no static map binding")
        mi_static = map_index[mname]
        d = decls[mi_static]
        key = stack_load(regs[2], d.key_size).astype(jnp.uint64)
        valid = key < jnp.uint64(d.max_entries)
        ki = jnp.minimum(key, jnp.uint64(d.max_entries - 1)).astype(jnp.int32)
        if hid == 1:  # map_lookup_elem(map, key*)
            enc = (jnp.uint64(_map_tag(mi_static))
                   | ((key & jnp.uint64(0xFFFFFFFF)) << jnp.uint64(24)))
            return jnp.where(valid, enc, jnp.uint64(0))
        if hid == 2:  # map_update_elem(map, key*, value*, flags)
            n_slots = d.value_size // 8
            row = [stack_load(regs[3] + jnp.uint64(8 * s), 8)
                   for s in range(n_slots)]
            newrow = jnp.stack(row)
            old = maps[d.name][ki]
            take = jnp.logical_and(P, valid)
            maps[d.name] = maps[d.name].at[ki].set(
                jnp.where(take, newrow, old))
            return jnp.where(valid, jnp.uint64(0), jnp.uint64(M64))
        if hid == 64:  # ema_update(map, key*, sample, weight)
            w = jnp.maximum(regs[4], jnp.uint64(1))
            old = maps[d.name][ki, 0]
            new = (old * (w - jnp.uint64(1)) + regs[3]) // w
            take = jnp.logical_and(P, valid)
            maps[d.name] = maps[d.name].at[ki, 0].set(
                jnp.where(take, new, old))
            return new
        raise JaxcError(f"helper {hid} not supported in-graph")

    return run, [d.name for d in decls]


def _alu_jax(base: str, width: int, a, b):
    mask32 = jnp.uint64(0xFFFFFFFF)
    if width == 32:
        a = a & mask32
        b = b & mask32

    def fin(x):
        return (x & mask32) if width == 32 else x

    if base == "mov":
        return fin(b)
    if base == "add":
        return fin(a + b)
    if base == "sub":
        return fin(a - b)
    if base == "mul":
        return fin(a * b)
    if base == "div":
        return fin(a // jnp.maximum(b, jnp.uint64(1)))  # b!=0 verified
    if base == "mod":
        return fin(a % jnp.maximum(b, jnp.uint64(1)))
    if base == "and":
        return a & b
    if base == "or":
        return fin(a | b)
    if base == "xor":
        return fin(a ^ b)
    sh = b & jnp.uint64(width - 1)
    if base == "lsh":
        return fin(a << sh)
    if base == "rsh":
        return fin(a >> sh)
    if base == "arsh":
        sa = a.astype(jnp.int64) if width == 64 else \
            (a & mask32).astype(jnp.uint32).astype(jnp.int32)
        return fin((sa >> sh.astype(sa.dtype)).astype(jnp.int64).astype(jnp.uint64))
    if base == "neg":
        return fin(jnp.uint64(0) - a)
    raise JaxcError(f"ALU base {base}")


def _cmp_jax(base: str, a, b):
    if base in ("jeq",):
        return a == b
    if base == "jne":
        return a != b
    if base == "jgt":
        return a > b
    if base == "jge":
        return a >= b
    if base == "jlt":
        return a < b
    if base == "jle":
        return a <= b
    if base == "jset":
        return (a & b) != 0
    sa, sb = a.astype(jnp.int64), b.astype(jnp.int64)
    return {"jsgt": sa > sb, "jsge": sa >= sb,
            "jslt": sa < sb, "jsle": sa <= sb}[base]


# ---------------------------------------------------------------------------
# Host <-> device map state conversion
# ---------------------------------------------------------------------------

def map_to_array(m: BpfMap) -> jnp.ndarray:
    """ArrayMap -> uint64[max_entries, slots] (for donating into the step)."""
    if not isinstance(m, ArrayMap):
        raise JaxcError(f"map {m.name} is not an array map")
    import numpy as np
    slots = m.value_size // 8
    out = np.zeros((m.max_entries, slots), dtype=np.uint64)
    for i in range(m.max_entries):
        buf = m.lookup(i.to_bytes(4, "little"))
        out[i] = np.frombuffer(bytes(buf), dtype="<u8")
    with jax.enable_x64(True):
        return jnp.asarray(out)


def array_to_map(arr, m: BpfMap) -> None:
    """Write device map state back into the host map (after a step)."""
    import numpy as np
    host = np.asarray(arr, dtype=np.uint64)
    for i in range(m.max_entries):
        m.update(i.to_bytes(4, "little"), host[i].tobytes())


def ctx_to_vec(ctx_buf: bytearray) -> jnp.ndarray:
    import numpy as np
    with jax.enable_x64(True):
        return jnp.asarray(np.frombuffer(bytes(ctx_buf), dtype="<u8"))


def compile_jax_jit(prog: Program):
    fn, names = compile_jax(prog)
    return jax.jit(fn), names
