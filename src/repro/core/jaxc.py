"""jaxc — verified policy bytecode compiled to pure JAX (the in-graph tier).

This tier goes beyond the paper: NCCLbpf's policies execute on the host
around each collective launch; on TPU the step function is one fused XLA
program, so we *if-convert* the verified policy into jnp ops and run it
INSIDE the compiled program.  Closed-loop adaptation (profiler map ->
tuner decision -> ``lax.switch`` branch) then happens per step with zero
host round-trips and zero retraces.

Why verification makes this possible:
  * the CFG decomposes into forward regions plus *natural loops with
    proven trip bounds* (shared :mod:`repro.core.cfg` layer) -> forward
    regions if-convert classically (execute every block under a
    predicate, writes select via ``jnp.where``), and each loop lowers to
    one ``lax.fori_loop`` running exactly ``bound + 1`` iterations with
    the machine state (regs / stack / ctx / maps / exit predicates)
    functionally threaded through the carry — early exits simply drop
    the ``active`` predicate so remaining iterations are no-ops
  * every memory insn has a statically known region (ctx / stack / one
    specific map)  -> each load/store lowers to a typed gather/scatter
  * bounded stack, bounded loops -> fixed-size traced state, zero
    retraces across decisions

Supported surface (JaxcError otherwise): ALU64/32, jumps, bounded loops,
bpf-to-bpf calls (``call_fn`` — callees are inlined under the caller's
predicate with a fresh frame, so zero-retrace and single-``fori_loop``
structure survive), ctx loads/stores (8-byte fields), stack loads/stores
(static or dynamic offset), ARRAY-family maps (u64-slot granularity;
``perdev_array`` exposes its current shard), RINGBUF maps
(reserve/submit/discard on the control words appended to the device
array — see :func:`repro.core.maps.device_shape`), HASH maps
(fixed-capacity open-addressing table, linear probing via a masked
probe-distance scan; inserts fail with E2BIG when full, deletes stay
host-side), LRU_HASH maps (masked-scan lookup/update over ``[value,
key, recency]`` rows plus a clock cell), helpers map_lookup_elem /
map_update_elem / ema_update / ringbuf_reserve / ringbuf_submit /
ringbuf_discard.  Wall-clock helpers are host-tier-only.

We pass ctx and maps as uint64 arrays under the scoped 64-bit context
(``repro.compat.enable_x64``); the surrounding model code stays 32-bit.
On the jax 0.4.x line the x64 scope must also wrap the *outer* jit call
boundary (see tests/test_jaxc.py) so inputs are not canonicalized down.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import helpers as H
from ..compat import enable_x64
from .cfg import CFG, Loop
from .isa import (FP_REG, Insn, STACK_SIZE, alu_base, alu_width, is_alu,
                  is_imm_form, is_jump_cond, is_load, is_store, jump_base,
                  mem_size)
from .maps import BpfMap
from .program import Program
from .verifier import verify_with_info

M64 = (1 << 64) - 1


class JaxcError(Exception):
    pass


# pointer encoding (mirrors the host JIT):
#   stack: 1<<32 | byte_off
#   ctx:   2<<32 | byte_off
#   map value (array map mi): (16+mi)<<56 | key<<24 | byte_off
_STACK_TAG = 1 << 32
_CTX_TAG = 2 << 32


def _map_tag(mi: int):
    return (16 + mi) << 56


_INGRAPH_KINDS = ("array", "perdev_array", "ringbuf", "hash", "lru_hash")
_INGRAPH_HIDS = (1, 2, 64, 65, 66, 67)


def check_supported(prog: Program, *, word_width: int = 64) -> None:
    """Raise JaxcError if ``prog`` cannot lower in-graph.

    ``word_width=32`` additionally applies the 32-bit-pair tier's
    restriction (no LRU recency/clock lowering), mirroring
    :mod:`repro.core.pallasc`'s compile-time rejection so eligibility
    probes agree with the compiler."""
    if word_width == 32:
        lru = [d.name for d in prog.maps if d.kind == "lru_hash"]
        if lru:
            raise JaxcError(
                f"policy '{prog.name}' uses lru_hash map(s) "
                f"{', '.join(repr(n) for n in lru)}; the 32-bit-pair tier "
                "does not lower LRU recency/clock metadata.  Workarounds: "
                "declare the map with kind=\"hash\", keep word_width=64, "
                "or run on a host tier (interp/jit/native)")
    for d in prog.maps:
        if d.kind not in _INGRAPH_KINDS:
            raise JaxcError(
                f"map '{d.name}' is {d.kind}; in-graph tier supports "
                f"{'/'.join(_INGRAPH_KINDS)} maps only")
        if d.value_size % 8:
            raise JaxcError(f"map '{d.name}': value_size must be 8-aligned")
        if d.kind == "hash" and d.key_size not in (4, 8):
            raise JaxcError(
                f"hash map '{d.name}': in-graph probing needs a 4- or "
                f"8-byte key (got {d.key_size})")
    bodies = [("main", prog.insns)]
    bodies += [(sp.name, sp.insns) for sp in prog.subprogs]
    for fname, insns in bodies:
        for pc, insn in enumerate(insns):
            if insn.op == "call" and insn.imm not in _INGRAPH_HIDS:
                hname = H.HELPERS[insn.imm].name
                if hname == "map_delete_elem":
                    raise JaxcError(
                        f"map_delete_elem (insn {pc} in {fname}) is not "
                        "available in-graph: deleting from a linear-"
                        "probing table would need tombstones; delete "
                        "from the host side instead (the bridge repacks "
                        "the table canonically on the next upload)")
                raise JaxcError(
                    f"helper {hname} (insn {pc} in {fname}) is not "
                    "available in-graph")


def _fn_infos(vinfo):
    """Per-function analysis artifacts: ``vinfo.fns`` when the verifier
    ran multi-function, else the top-level object (which quacks the
    same) as the sole entry."""
    fns = getattr(vinfo, "fns", None)
    return list(fns) if fns else [vinfo]


def written_map_names(prog: Program, vinfo) -> frozenset:
    """Maps the program can mutate, from the verifier's region facts.

    A map is written iff some store's proven region is a value cell of it,
    or a mutating helper (``map_update_elem`` / ``ema_update`` / any
    ringbuf helper — the control words advance) statically binds to it,
    or a ``map_lookup_elem`` binds to an LRU map (a hit refreshes
    recency; plain-hash lookups mutate nothing).  Subprogram bodies
    count: a map a callee writes is written.  The host bridge uses this
    to sync back ONLY these maps after a device call — lookup-only
    telemetry inputs never round-trip."""
    kinds = {d.name: d.kind for d in prog.maps}
    out = set()
    for fi in _fn_infos(vinfo):
        for pc, insn in enumerate(fi.insns):
            if is_store(insn.op):
                info = fi.mem_info.get(pc)
                if info is not None and info[0] not in ("ctx", "stack"):
                    out.add(info[1])
            elif insn.op == "call" and insn.imm in (2, 64, 65, 66, 67):
                mname = fi.call_map.get(pc)
                if mname is not None:
                    out.add(mname)
            elif insn.op == "call" and insn.imm == 1:
                mname = fi.call_map.get(pc)
                if mname is not None and kinds.get(mname) == "lru_hash":
                    out.add(mname)
    return frozenset(out)


def _u64(x):
    return jnp.asarray(x, jnp.uint64)


def _pred_or(ps):
    p = ps[0]
    for q in ps[1:]:
        p = jnp.logical_or(p, q)
    return p


def _sel(p, new, old):
    return jnp.where(p, new, old)


class _Lowerer:
    """One policy invocation lowered block-by-block under predicates.

    Machine state lives in attributes (regs/stack/ctx/maps/done/ret) so
    straight-line emission stays imperative; loops snapshot the state
    into a ``fori_loop`` carry and restore from the final carry.

    The CFG walk (regions, predicates, loop carries) is representation-
    agnostic: every place a 64-bit machine value is materialized,
    selected, computed, or compared goes through the ``_imm`` / ``_coerce``
    / ``_sel`` / ``_alu`` / ``_cmp`` hooks plus the memory/helper methods.
    The base class keeps the native-uint64 representation; the 32-bit-pair
    lowering (:mod:`repro.core.lower32`, for Mosaic's 32-bit-only integer
    units) subclasses it and swaps only those hooks."""

    def __init__(self, prog: Program, vinfo, ctx_vec, map_arrays):
        self.prog = prog
        self.vinfo = vinfo
        # per-function analysis artifacts: bpf-to-bpf callees are
        # *inlined* at lowering time (`_inline_call`), retargeting
        # fninfo/cfg/insns at the callee for the duration of its body
        self.fns = _fn_infos(vinfo)
        self.fninfo = self.fns[0]
        self.cfg: CFG = self.fninfo.cfg
        self.insns = list(prog.insns)
        self.decls = list(prog.maps)
        self.map_index = {d.name: i for i, d in enumerate(self.decls)}
        self.map_names = [d.name for d in self.decls]
        self._init_state(ctx_vec, map_arrays)

    # ---- representation hooks (overridden by the 32-bit-pair lowerer) ----
    def _init_state(self, ctx_vec, map_arrays) -> None:
        self.ctx = jnp.asarray(ctx_vec, jnp.uint64)
        self.maps = {k: jnp.asarray(v, jnp.uint64)
                     for k, v in map_arrays.items()}
        self.regs: List[jnp.ndarray] = [_u64(0)] * 11
        self.regs[1] = self._imm(_CTX_TAG)
        self.regs[FP_REG] = self._imm(_STACK_TAG | STACK_SIZE)
        self.stack = self._fresh_stack()
        self.done = jnp.asarray(False)
        self.ret = self._imm(0)

    def _fresh_stack(self):
        """A zeroed frame in the machine representation (u64 slots)."""
        return jnp.zeros(STACK_SIZE // 8, jnp.uint64)

    def _imm(self, imm: int):
        """Materialize a 64-bit immediate in the machine representation."""
        return jnp.uint64(imm & M64)

    def _coerce(self, val):
        """Coerce a helper/ALU result into the machine representation."""
        return jnp.asarray(val, jnp.uint64)

    def _sel(self, p, new, old):
        """Predicated select over machine values."""
        return _sel(p, new, old)

    def _alu(self, base: str, width: int, a, b):
        return _alu_jax(base, width, a, b)

    def _cmp(self, base: str, a, b):
        return _cmp_jax(base, a, b)

    # ---- entry -----------------------------------------------------------
    def run(self):
        top = {h for h, L in self.cfg.loops.items() if L.parent is None}
        out = self._exec_region(
            list(range(self.cfg.n)), {0: [jnp.asarray(True)]}, expand=top)
        if out:
            # every top-level out-edge is an exit (routed via done/ret);
            # residue is a CFG bug — raise even under python -O
            raise JaxcError(f"unrouted edges at top level: {sorted(out)}")
        return self.ret, self.ctx, self.maps

    # ---- region execution ------------------------------------------------
    def _exec_region(self, block_list: List[int],
                     incoming: Dict[int, list], expand) -> Dict[int, list]:
        region = set(block_list)
        inc: Dict[int, list] = {b: list(ps) for b, ps in incoming.items()}
        out: Dict[int, list] = {}
        consumed = set()

        def route(src: int, tgt: int, p) -> None:
            if tgt == CFG.EXIT:
                return  # exit insns route through done/ret directly
            if tgt in region and tgt > src:
                inc.setdefault(tgt, []).append(p)
            else:
                # leaves the region, or is a back edge to its header
                out.setdefault(tgt, []).append(p)

        for b in block_list:
            if b in consumed:
                continue
            ps = inc.get(b)
            if b in expand:
                L = self.cfg.loops[b]
                consumed |= L.body
                if ps is None:
                    continue  # statically unreachable loop
                self._lower_loop(L, _pred_or(ps),
                                 lambda tgt, p, b=b: route(b, tgt, p))
                continue
            if ps is None:
                continue  # statically unreachable
            self._exec_block(b, _pred_or(ps),
                             lambda tgt, p, b=b: route(b, tgt, p))
        return out

    def _exec_block(self, b: int, P, route) -> None:
        insns = self.insns
        start, end = self.cfg.ranges[b]
        for pc in range(start, end):
            insn = insns[pc]
            op = insn.op
            if op == "exit":
                take = jnp.logical_and(P, jnp.logical_not(self.done))
                self.ret = self._sel(take, self.regs[0], self.ret)
                self.done = jnp.logical_or(self.done, P)
                return
            if op == "ja":
                route(self.cfg.succs[b][0], P)
                return
            if is_jump_cond(op):
                a = self.regs[insn.dst]
                v = self._imm(insn.imm) if is_imm_form(op) \
                    else self.regs[insn.src]
                c = self._cmp(jump_base(op), a, v)
                taken, fall = self.cfg.succs[b]
                route(taken, jnp.logical_and(P, c))
                route(fall, jnp.logical_and(P, jnp.logical_not(c)))
                return
            self._exec_straight(pc, insn, P)
        route(self.cfg.succs[b][0], P)  # fall-through block

    # ---- straight-line instructions --------------------------------------
    def _wreg(self, P, idx: int, val) -> None:
        self.regs[idx] = self._sel(P, self._coerce(val), self.regs[idx])

    def _exec_straight(self, pc: int, insn: Insn, P) -> None:
        op = insn.op
        if op == "lddw":
            self._wreg(P, insn.dst, self._imm(insn.imm))
            return
        if op == "ldmap":
            mi = self.map_index[insn.map_name]
            self._wreg(P, insn.dst, self._imm(_map_tag(mi)))
            return
        if op == "call":
            ret = self._call(pc, insn, P)
            self._wreg(P, 0, ret)
            for r in (1, 2, 3, 4, 5):
                self._wreg(P, r, self._imm(0))
            return
        if op == "call_fn":
            self._inline_call(insn.imm, P)
            return
        if is_alu(op):
            a = self.regs[insn.dst]
            b = self._imm(insn.imm) if is_imm_form(op) \
                else self.regs[insn.src]
            self._wreg(P, insn.dst,
                       self._alu(alu_base(op), alu_width(op), a, b))
            return
        if is_load(op):
            self._exec_load(pc, insn, P)
            return
        if is_store(op):
            self._exec_store(pc, insn, P)
            return
        raise JaxcError(f"unhandled op {op}")

    # ---- memory -----------------------------------------------------------
    def _stack_load(self, ptr, size: int):
        slot = ((ptr & jnp.uint64(0xFFFFFFFF)) >> 3).astype(jnp.int32)
        word = self.stack[slot]
        if size == 8:
            return word
        sh = ((ptr & jnp.uint64(7)) * 8).astype(jnp.uint64)
        mask = jnp.uint64((1 << (8 * size)) - 1)
        return (word >> sh) & mask

    def _stack_store(self, P, ptr, size: int, val) -> None:
        off = ptr & jnp.uint64(0xFFFFFFFF)
        slot = (off >> 3).astype(jnp.int32)
        word = self.stack[slot]
        if size == 8:
            new = jnp.asarray(val, jnp.uint64)
        else:
            sh = ((off & jnp.uint64(7)) * 8).astype(jnp.uint64)
            mask = jnp.uint64((1 << (8 * size)) - 1)
            new = ((word & ~(mask << sh))
                   | ((jnp.asarray(val, jnp.uint64) & mask) << sh))
        self.stack = self.stack.at[slot].set(_sel(P, new, word))

    @staticmethod
    def _mapval_decode(ptr):
        mi = ((ptr >> jnp.uint64(56)) - 16).astype(jnp.int32)
        key = ((ptr >> jnp.uint64(24)) & jnp.uint64(0xFFFFFFFF)).astype(
            jnp.int32)
        off = ptr & jnp.uint64(0xFFFFFF)
        return mi, key, off

    def _exec_load(self, pc: int, insn: Insn, P) -> None:
        size = mem_size(insn.op)
        region, mname, base = self.fninfo.mem_info[pc]
        ptr = self.regs[insn.src] + jnp.uint64(insn.off & M64)
        if region == "ctx":
            off = base + insn.off  # static (verified)
            val = self.ctx[off // 8]
            if size < 8:
                val = val & jnp.uint64((1 << (8 * size)) - 1)
        elif region == "stack":
            val = self._stack_load(ptr, size)
        else:  # mapval
            _, key, off = self._mapval_decode(ptr)
            slot = (off >> jnp.uint64(3)).astype(jnp.int32)
            val = self.maps[mname][key, slot]
            if size < 8:
                val = val & jnp.uint64((1 << (8 * size)) - 1)
        self._wreg(P, insn.dst, val)

    def _exec_store(self, pc: int, insn: Insn, P) -> None:
        size = mem_size(insn.op)
        region, mname, base = self.fninfo.mem_info[pc]
        val = jnp.uint64(insn.imm & M64) if not insn.op.startswith("stx") \
            else self.regs[insn.src]
        ptr = self.regs[insn.dst] + jnp.uint64(insn.off & M64)
        if region == "ctx":
            slot = (base + insn.off) // 8
            self.ctx = self.ctx.at[slot].set(_sel(P, val, self.ctx[slot]))
        elif region == "stack":
            self._stack_store(P, ptr, size, val)
        else:  # mapval
            _, key, off = self._mapval_decode(ptr)
            slot = (off >> jnp.uint64(3)).astype(jnp.int32)
            old = self.maps[mname][key, slot]
            self.maps[mname] = self.maps[mname].at[key, slot].set(
                _sel(P, val, old))

    # ---- helpers -----------------------------------------------------------
    def _call(self, pc: int, insn: Insn, P):
        hid = insn.imm
        # the verifier proved exactly which map reaches this call site
        mname = self.fninfo.call_map.get(pc)
        if mname is None:
            raise JaxcError(f"helper at insn {pc} has no static map binding")
        mi = self.map_index[mname]
        d = self.decls[mi]
        if d.kind == "ringbuf":
            return self._call_ringbuf(hid, mi, d, P)
        if d.kind == "lru_hash":
            return self._call_lru(hid, mi, d, P)
        if d.kind == "hash":
            return self._call_hash(hid, mi, d, P)
        key = self._stack_load(self.regs[2], d.key_size).astype(jnp.uint64)
        valid = key < jnp.uint64(d.max_entries)
        ki = jnp.minimum(key, jnp.uint64(d.max_entries - 1)).astype(jnp.int32)
        if hid == 1:  # map_lookup_elem(map, key*)
            enc = (jnp.uint64(_map_tag(mi))
                   | ((key & jnp.uint64(0xFFFFFFFF)) << jnp.uint64(24)))
            return jnp.where(valid, enc, jnp.uint64(0))
        if hid == 2:  # map_update_elem(map, key*, value*, flags)
            n_slots = d.value_size // 8
            row = [self._stack_load(self.regs[3] + jnp.uint64(8 * s), 8)
                   for s in range(n_slots)]
            newrow = jnp.stack(row)
            old = self.maps[d.name][ki]
            take = jnp.logical_and(P, valid)
            self.maps[d.name] = self.maps[d.name].at[ki].set(
                jnp.where(take, newrow, old))
            return jnp.where(valid, jnp.uint64(0), jnp.uint64(M64))
        if hid == 64:  # ema_update(map, key*, sample, weight)
            w = jnp.maximum(self.regs[4], jnp.uint64(1))
            old = self.maps[d.name][ki, 0]
            new = (old * (w - jnp.uint64(1)) + self.regs[3]) // w
            take = jnp.logical_and(P, valid)
            self.maps[d.name] = self.maps[d.name].at[ki, 0].set(
                jnp.where(take, new, old))
            return new
        raise JaxcError(f"helper {hid} not supported in-graph")

    def _call_ringbuf(self, hid: int, mi: int, d, P):
        """reserve/submit/discard on the control words the device layout
        appends to the record rows (``maps.device_shape``): head / tail /
        drops / pending, mirroring :class:`repro.core.maps.RingBufMap`
        cursor-for-cursor so vm differentials stay bit-identical."""
        arr = self.maps[d.name]
        slots = d.value_size // 8
        ctl = lambda w: (d.max_entries + w // slots, w % slots)  # noqa: E731
        (hr, hc), (pr, pc2) = ctl(0), ctl(3)
        head, pend = arr[hr, hc], arr[pr, pc2]
        if hid == 66:  # ringbuf_submit: publish the pending record
            head2 = head + pend
            arr = arr.at[hr, hc].set(jnp.where(P, head2, head))
            arr = arr.at[pr, pc2].set(jnp.where(P, jnp.uint64(0), pend))
            self.maps[d.name] = arr
            return jnp.uint64(0)
        if hid == 67:  # ringbuf_discard: abandon the pending record
            arr = arr.at[pr, pc2].set(jnp.where(P, jnp.uint64(0), pend))
            self.maps[d.name] = arr
            return jnp.uint64(0)
        if hid != 65:
            raise JaxcError(f"helper {hid} on ringbuf map '{d.name}'")
        # ringbuf_reserve: implicitly commit a still-pending reservation,
        # then NULL (+1 drop) on full, else mark the next row pending
        (tr, tc), (dr, dc) = ctl(1), ctl(2)
        tail, drops = arr[tr, tc], arr[dr, dc]
        head1 = head + pend
        full = (head1 - tail) >= jnp.uint64(d.max_entries)
        arr = arr.at[hr, hc].set(jnp.where(P, head1, head))
        arr = arr.at[pr, pc2].set(jnp.where(
            P, jnp.where(full, jnp.uint64(0), jnp.uint64(1)), pend))
        arr = arr.at[dr, dc].set(jnp.where(
            jnp.logical_and(P, full), drops + jnp.uint64(1), drops))
        self.maps[d.name] = arr
        row = (head1 % jnp.uint64(d.max_entries)) & jnp.uint64(0xFFFFFFFF)
        enc = jnp.uint64(_map_tag(mi)) | (row << jnp.uint64(24))
        return jnp.where(full, jnp.uint64(0), enc)

    def _call_lru(self, hid: int, mi: int, d, P):
        """lookup/update on the LRU device layout: ``max_entries`` rows of
        ``[value slots..., key, recency]`` plus the clock cell at
        ``[max_entries, 0]`` (``maps.device_shape``).  Victim selection is
        ``argmin(recency)`` — first minimum, so free rows (recency 0) win
        and ties break to the lowest index, matching the host map."""
        arr = self.maps[d.name]
        slots = d.value_size // 8
        kcol, rcol = slots, slots + 1
        key = self._stack_load(self.regs[2], d.key_size).astype(jnp.uint64)
        keys = arr[:d.max_entries, kcol]
        recs = arr[:d.max_entries, rcol]
        match = jnp.logical_and(recs > jnp.uint64(0), keys == key)
        found = jnp.any(match)
        idx = jnp.argmax(match).astype(jnp.int32)
        clock = arr[d.max_entries, 0]
        clock1 = clock + jnp.uint64(1)
        if hid == 1:  # map_lookup_elem: a hit refreshes recency
            take = jnp.logical_and(P, found)
            arr = arr.at[d.max_entries, 0].set(
                jnp.where(take, clock1, clock))
            arr = arr.at[idx, rcol].set(
                jnp.where(take, clock1, arr[idx, rcol]))
            self.maps[d.name] = arr
            enc = (jnp.uint64(_map_tag(mi))
                   | (idx.astype(jnp.uint64) << jnp.uint64(24)))
            return jnp.where(found, enc, jnp.uint64(0))
        # the remaining helpers claim a row: the hit, else the LRU victim
        victim = jnp.argmin(recs).astype(jnp.int32)
        tgt = jnp.where(found, idx, victim)
        oldrow = lax.dynamic_slice(
            arr, (tgt, jnp.int32(0)), (1, arr.shape[1]))[0]
        if hid == 2:  # map_update_elem: overwrite hit else evict victim
            newrow = jnp.stack(
                [self._stack_load(self.regs[3] + jnp.uint64(8 * s), 8)
                 for s in range(slots)])
            ret = jnp.uint64(0)
        elif hid == 64:  # ema_update: RMW slot 0 (miss seeds from old=0)
            w = jnp.maximum(self.regs[4], jnp.uint64(1))
            old = jnp.where(found, oldrow[0], jnp.uint64(0))
            new = (old * (w - jnp.uint64(1)) + self.regs[3]) // w
            keep = jnp.where(found, oldrow[:slots],
                             jnp.zeros(slots, jnp.uint64))
            newrow = keep.at[0].set(new)
            ret = new
        else:
            raise JaxcError(f"helper {hid} on lru_hash map '{d.name}'")
        full_new = jnp.concatenate([newrow, jnp.stack([key, clock1])])
        sel = jnp.where(P, full_new, oldrow)
        arr = lax.dynamic_update_slice(
            arr, sel[None, :], (tgt, jnp.int32(0)))
        arr = arr.at[d.max_entries, 0].set(jnp.where(P, clock1, clock))
        self.maps[d.name] = arr
        return ret

    def _hash_probe(self, arr, d, key):
        """Open-addressing probe over the hash device layout
        (``max_entries`` rows of ``[value slots..., key, used]`` plus the
        occupancy cell at ``[max_entries, 0]`` — ``maps.device_shape``).

        Linear probing in probe-distance order from ``hash_slot(key)``:
        the scan stops at the first row that is a key match or empty,
        exactly the sequential probe's termination — so the selected row
        matches the host map's packing (``HashMap.to_device`` inserts by
        the same probe sequence, and in-graph deletion is rejected, so
        no tombstone can sit between the home slot and the key).

        Returns ``(first, hit, can_claim)``: the stopping row index, a
        key-match predicate, and whether a miss may claim ``first`` as a
        fresh slot (False when the table is full and the key absent)."""
        slots = d.value_size // 8
        kcol, ucol = slots, slots + 1
        cap = d.max_entries
        keys = arr[:cap, kcol]
        used = arr[:cap, ucol] > jnp.uint64(0)
        h = ((key & jnp.uint64(0xFFFFFFFF)) ^ (key >> jnp.uint64(32))) \
            % jnp.uint64(cap)
        dist = (jnp.arange(cap, dtype=jnp.uint64) - h) % jnp.uint64(cap)
        is_match = jnp.logical_and(used, keys == key)
        stop = jnp.logical_or(is_match, jnp.logical_not(used))
        first = jnp.argmin(
            jnp.where(stop, dist, jnp.uint64(cap))).astype(jnp.int32)
        has_stop = jnp.any(stop)
        hit = jnp.logical_and(has_stop, is_match[first])
        can_claim = jnp.logical_and(has_stop, jnp.logical_not(hit))
        return first, hit, can_claim

    def _call_hash(self, hid: int, mi: int, d, P):
        """lookup/update/ema on the open-addressing hash layout.  A full
        table rejects inserts with -1 (E2BIG), matching the host map;
        lookups mutate nothing (unlike LRU there is no recency)."""
        arr = self.maps[d.name]
        slots = d.value_size // 8
        cap = d.max_entries
        key = self._stack_load(self.regs[2], d.key_size).astype(jnp.uint64)
        first, hit, can_claim = self._hash_probe(arr, d, key)
        if hid == 1:  # map_lookup_elem: encode the physical row index
            enc = (jnp.uint64(_map_tag(mi))
                   | (first.astype(jnp.uint64) << jnp.uint64(24)))
            return jnp.where(hit, enc, jnp.uint64(0))
        ok = jnp.logical_or(hit, can_claim)
        oldrow = lax.dynamic_slice(
            arr, (first, jnp.int32(0)), (1, arr.shape[1]))[0]
        if hid == 2:  # map_update_elem: overwrite hit else claim a slot
            newvals = jnp.stack(
                [self._stack_load(self.regs[3] + jnp.uint64(8 * s), 8)
                 for s in range(slots)])
            ret = jnp.where(ok, jnp.uint64(0), jnp.uint64(M64))
        elif hid == 64:  # ema_update: RMW slot 0 (miss seeds from old=0)
            w = jnp.maximum(self.regs[4], jnp.uint64(1))
            old = jnp.where(hit, oldrow[0], jnp.uint64(0))
            new = (old * (w - jnp.uint64(1)) + self.regs[3]) // w
            keep = jnp.where(hit, oldrow[:slots],
                             jnp.zeros(slots, jnp.uint64))
            newvals = keep.at[0].set(new)
            ret = new
        else:
            raise JaxcError(f"helper {hid} on hash map '{d.name}'")
        take = jnp.logical_and(P, ok)
        full_new = jnp.concatenate(
            [newvals, jnp.stack([key, jnp.uint64(1)])])
        sel = jnp.where(take, full_new, oldrow)
        arr = lax.dynamic_update_slice(
            arr, sel[None, :], (first, jnp.int32(0)))
        occ = arr[cap, 0]
        arr = arr.at[cap, 0].set(jnp.where(
            jnp.logical_and(P, can_claim), occ + jnp.uint64(1), occ))
        self.maps[d.name] = arr
        return ret

    # ---- bpf-to-bpf calls ---------------------------------------------------
    def _inline_call(self, idx: int, P) -> None:
        """``call_fn``: inline the callee's lowered body under the
        caller's predicate.  The callee gets a fresh frame — zeroed
        stack, fresh regs with r1-r5 copied in — while ctx and maps stay
        shared (writes inside the callee are already gated on ``P``
        through its block predicates).  done/ret are callee-local, so a
        callee ``exit`` returns to the caller's continuation instead of
        ending the program.  Inlining (vs an out-of-line call) keeps the
        whole program one straight trace: zero retraces, and loops
        containing calls still lower to a single ``fori_loop``."""
        callee = self.fns[1 + idx]
        saved = (self.fninfo, self.cfg, self.insns, self.stack,
                 self.regs, self.done, self.ret)
        self.fninfo = callee
        self.cfg = callee.cfg
        self.insns = list(callee.insns)
        self.stack = self._fresh_stack()
        cregs = [self._imm(0)] * 11
        for r in (1, 2, 3, 4, 5):
            cregs[r] = saved[4][r]
        cregs[FP_REG] = self._imm(_STACK_TAG | STACK_SIZE)
        self.regs = cregs
        self.done = jnp.asarray(False)
        self.ret = self._imm(0)

        top = {h for h, L in self.cfg.loops.items() if L.parent is None}
        out = self._exec_region(list(range(self.cfg.n)), {0: [P]},
                                expand=top)
        if out:
            raise JaxcError(
                f"unrouted edges in subprogram '{callee.name}': "
                f"{sorted(out)}")
        ret = self.ret
        (self.fninfo, self.cfg, self.insns, self.stack,
         self.regs, self.done, self.ret) = saved
        self._wreg(P, 0, ret)
        for r in (1, 2, 3, 4, 5):
            self._wreg(P, r, self._imm(0))

    # ---- loops -------------------------------------------------------------
    def _snapshot(self, active, exit_preds):
        return (active, tuple(self.regs), self.stack, self.ctx,
                tuple(self.maps[n] for n in self.map_names),
                self.done, self.ret, tuple(exit_preds))

    def _restore(self, carry):
        active, regs, stack, ctx, maps_t, done, ret, exps = carry
        self.regs = list(regs)
        self.stack = stack
        self.ctx = ctx
        self.maps = {n: m for n, m in zip(self.map_names, maps_t)}
        self.done = done
        self.ret = ret
        return active, list(exps)

    def _lower_loop(self, L: Loop, entry_pred, route) -> None:
        """One natural loop -> one ``lax.fori_loop``.

        The carry threads (active, regs, stack, ctx, maps, done, ret,
        per-exit-target predicates).  Each iteration executes header +
        body under ``active``; taking an exit edge latches that target's
        predicate and drops out of ``active``, so later iterations leave
        the state untouched.  The verifier's trip bound caps the counter:
        ``bound`` body passes plus one final header visit that takes the
        exit test."""
        h = L.header
        bound = self.fninfo.loop_bounds[h]
        body_blocks = sorted(L.body)
        exit_targets = list(L.exit_targets)
        inner = {M.header for M in self.cfg.inner_loops(L)}

        false_ = jnp.asarray(False)
        init = self._snapshot(entry_pred, [false_] * len(exit_targets))

        def body(_k, carry):
            active, exps = self._restore(carry)
            out = self._exec_region(body_blocks, {h: [active]},
                                    expand=inner)
            next_active = _pred_or(out.pop(h, [false_]))
            new_exps = []
            for tgt, e in zip(exit_targets, exps):
                new_exps.append(jnp.logical_or(
                    e, _pred_or(out.pop(tgt, [false_]))))
            if out:
                raise JaxcError(
                    f"loop at block {h}: unrouted edges {sorted(out)}")
            return self._snapshot(next_active, new_exps)

        final = lax.fori_loop(0, bound + 1, body, init)
        _, exps = self._restore(final)
        for tgt, e in zip(exit_targets, exps):
            route(tgt, e)


def compile_jax(prog: Program, vinfo=None):
    """Return (fn, map_names).

    ``fn(ctx_vec, map_arrays) -> (ret, ctx_vec_out, map_arrays_out)`` where
    ``ctx_vec`` is uint64[n_fields] and ``map_arrays`` is a dict
    name -> uint64[max_entries, value_slots].  Pure; jit/vmap/scan-safe.

    ``vinfo`` reuses a prior :func:`verify_with_info` result (the shared
    cfg / loop_bounds / mem_info artifacts) so callers that already
    verified — the runtime's load path, the pallas tier — pay for one
    static pass, not two.
    """
    check_supported(prog)
    if vinfo is None:
        vinfo = verify_with_info(prog)

    def run(ctx_vec, map_arrays: Dict[str, jnp.ndarray]):
        with enable_x64(True):
            return _Lowerer(prog, vinfo, ctx_vec, map_arrays).run()

    return run, [d.name for d in prog.maps]


def _alu_jax(base: str, width: int, a, b):
    mask32 = jnp.uint64(0xFFFFFFFF)
    if width == 32:
        a = a & mask32
        b = b & mask32

    def fin(x):
        return (x & mask32) if width == 32 else x

    if base == "mov":
        return fin(b)
    if base == "add":
        return fin(a + b)
    if base == "sub":
        return fin(a - b)
    if base == "mul":
        return fin(a * b)
    if base == "div":
        return fin(a // jnp.maximum(b, jnp.uint64(1)))  # b!=0 verified
    if base == "mod":
        return fin(a % jnp.maximum(b, jnp.uint64(1)))
    if base == "and":
        return a & b
    if base == "or":
        return fin(a | b)
    if base == "xor":
        return fin(a ^ b)
    sh = b & jnp.uint64(width - 1)
    if base == "lsh":
        return fin(a << sh)
    if base == "rsh":
        return fin(a >> sh)
    if base == "arsh":
        sa = a.astype(jnp.int64) if width == 64 else \
            (a & mask32).astype(jnp.uint32).astype(jnp.int32)
        return fin((sa >> sh.astype(sa.dtype)).astype(jnp.int64)
                   .astype(jnp.uint64))
    if base == "neg":
        return fin(jnp.uint64(0) - a)
    raise JaxcError(f"ALU base {base}")


def _cmp_jax(base: str, a, b):
    if base in ("jeq",):
        return a == b
    if base == "jne":
        return a != b
    if base == "jgt":
        return a > b
    if base == "jge":
        return a >= b
    if base == "jlt":
        return a < b
    if base == "jle":
        return a <= b
    if base == "jset":
        return (a & b) != 0
    sa, sb = a.astype(jnp.int64), b.astype(jnp.int64)
    return {"jsgt": sa > sb, "jsge": sa >= sb,
            "jslt": sa < sb, "jsle": sa <= sb}[base]


# ---------------------------------------------------------------------------
# Host <-> device map state conversion
# ---------------------------------------------------------------------------

def map_to_array(m: BpfMap) -> jnp.ndarray:
    """Host map -> uint64[rows, cols] device image.

    Delegates to the map's own ``to_device`` protocol (``maps.py``):
    array-family maps export their slots, ringbufs append control words,
    LRU maps append key/recency columns and the clock row.  Raises for
    kinds with no device representation (plain hash)."""
    from .maps import MapError
    try:
        out = m.to_device()
    except MapError as e:
        raise JaxcError(str(e)) from None
    with enable_x64(True):
        return jnp.asarray(out)


def array_to_map(arr, m: BpfMap) -> None:
    """Write device map state back into the host map (after a step)."""
    import numpy as np
    m.from_device(np.asarray(arr, dtype=np.uint64))


def ctx_to_vec(ctx_buf: bytearray) -> jnp.ndarray:
    import numpy as np
    with enable_x64(True):
        return jnp.asarray(np.frombuffer(bytes(ctx_buf), dtype="<u8"))


def compile_jax_jit(prog: Program):
    fn, names = compile_jax(prog)
    return jax.jit(fn), names
