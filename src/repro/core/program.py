"""Program container — the "BPF ELF object" analogue.

A :class:`Program` bundles a section type (tuner/profiler/net), the
instruction list, and declared map dependencies.  Loading a program into the
runtime verifies it against its declared section's context type and resolves
map names against the shared registry.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .context import CTX_TYPES, CtxType
from .isa import Insn, validate_insn


@dataclasses.dataclass(frozen=True)
class MapDecl:
    name: str
    kind: str               # array | hash | percpu_array
    key_size: int = 4
    value_size: int = 8
    max_entries: int = 64
    # shared=True pins the map into the registry's cross-plugin namespace
    # at load time (MapRegistry.get_pinned) — the paper's composability
    # substrate: profiler and tuner programs share state by name
    shared: bool = False
    # per-value-slot shard-merge reduce for mesh-scale telemetry
    # (core.shardmerge): "sum" merges per-shard deltas by wrapping u64
    # addition (the counter idiom), "max" takes the cell from the shard
    # with the highest write cursor (the EMA / last-writer idiom).
    # Shorter tuples pad with "sum"; () means every slot is a counter.
    merge: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class SubProgram:
    """A callee reachable via ``call_fn`` — the "static function in the
    same ELF" analogue.  Arguments arrive in r1..r5 (scalars only, the
    verifier enforces it), the result returns in r0, and each activation
    gets a fresh 512-byte stack frame."""
    name: str
    insns: Tuple[Insn, ...]
    n_args: int = 0


@dataclasses.dataclass
class Program:
    name: str
    section: str            # tuner | profiler | net
    insns: List[Insn]
    maps: Tuple[MapDecl, ...] = ()
    source: Optional[str] = None   # original restricted-Python/asm text
    subprogs: Tuple[SubProgram, ...] = ()

    def __post_init__(self):
        if self.section not in CTX_TYPES:
            raise ValueError(f"unknown section {self.section!r}")
        for i, insn in enumerate(self.insns):
            validate_insn(insn, i)
            self._check_call_fn(insn, i, "main")
        for sp in self.subprogs:
            for i, insn in enumerate(sp.insns):
                validate_insn(insn, i)
                self._check_call_fn(insn, i, sp.name)

    def _check_call_fn(self, insn: Insn, i: int, where: str) -> None:
        if insn.op == "call_fn" and not (0 <= insn.imm < len(self.subprogs)):
            raise ValueError(
                f"{where} insn {i}: call_fn fn{insn.imm} out of range "
                f"(program has {len(self.subprogs)} subprogram(s))")

    @property
    def ctx_type(self) -> CtxType:
        return CTX_TYPES[self.section]

    def map_decl(self, name: str) -> MapDecl:
        for d in self.maps:
            if d.name == name:
                return d
        raise KeyError(f"program {self.name}: map {name!r} not declared")

    def disasm(self) -> str:
        return "\n".join(f"{i:4d}: {insn!r}" for i, insn in enumerate(self.insns))

    def __len__(self) -> int:
        return len(self.insns)
