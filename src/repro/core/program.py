"""Program container — the "BPF ELF object" analogue.

A :class:`Program` bundles a section type (tuner/profiler/net), the
instruction list, and declared map dependencies.  Loading a program into the
runtime verifies it against its declared section's context type and resolves
map names against the shared registry.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .context import CTX_TYPES, CtxType
from .isa import Insn, validate_insn


@dataclasses.dataclass(frozen=True)
class MapDecl:
    name: str
    kind: str               # array | hash | percpu_array
    key_size: int = 4
    value_size: int = 8
    max_entries: int = 64
    # shared=True pins the map into the registry's cross-plugin namespace
    # at load time (MapRegistry.get_pinned) — the paper's composability
    # substrate: profiler and tuner programs share state by name
    shared: bool = False


@dataclasses.dataclass
class Program:
    name: str
    section: str            # tuner | profiler | net
    insns: List[Insn]
    maps: Tuple[MapDecl, ...] = ()
    source: Optional[str] = None   # original restricted-Python/asm text

    def __post_init__(self):
        if self.section not in CTX_TYPES:
            raise ValueError(f"unknown section {self.section!r}")
        for i, insn in enumerate(self.insns):
            validate_insn(insn, i)

    @property
    def ctx_type(self) -> CtxType:
        return CTX_TYPES[self.section]

    def map_decl(self, name: str) -> MapDecl:
        for d in self.maps:
            if d.name == name:
                return d
        raise KeyError(f"program {self.name}: map {name!r} not declared")

    def disasm(self) -> str:
        return "\n".join(f"{i:4d}: {insn!r}" for i, insn in enumerate(self.insns))

    def __len__(self) -> int:
        return len(self.insns)
