"""Reference interpreter for repro policy bytecode.

The interpreter is the semantic ground truth: the host JIT and the jaxc
in-graph compiler are both property-tested against it.  It performs dynamic
checks (bounds, null deref, div-by-zero) so that tests can also demonstrate
what *would* happen if an unverified program ran — e.g. the SIGSEGV analogue
in the paper's safety comparison.

Values:
  * scalars       — python ints, u64 wrap-around semantics
  * pointers      — ``Ptr(kind, mem, off)`` where mem is a bytearray
                    (ctx / stack / map value) or a BpfMap (map pointer)
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Callable, Dict, List, Optional

from . import faults as _faults
from . import helpers as H
from .context import PolicyContextValues
from .isa import (FP_REG, Insn, STACK_SIZE, alu_base, alu_width, is_alu,
                  is_imm_form, is_jump_cond, is_load, is_store, jump_base,
                  mem_size, s64, u32, u64)
from .maps import BpfMap

INSN_BUDGET = 1_000_000  # kernel-style dynamic budget (default fuel)


class VMError(Exception):
    """Runtime fault — the analogue of SIGSEGV / lockup in a native plugin."""


@dataclasses.dataclass
class Ptr:
    kind: str          # "ctx" | "stack" | "mapval" | "map"
    mem: object        # bytearray | BpfMap
    off: int = 0
    # mapval pointers remember their owning map so stores through them
    # bump the map's content version (device-bridge dirty tracking)
    owner: object = None

    def __add__(self, k: int) -> "Ptr":
        return Ptr(self.kind, self.mem, self.off + k, self.owner)


def _load(mem: bytearray, off: int, size: int, what: str) -> int:
    if off < 0 or off + size > len(mem):
        raise VMError(f"out-of-bounds read: {what}[{off}:{off+size}] of {len(mem)}B")
    return int.from_bytes(mem[off:off + size], "little", signed=False)


def _store(mem: bytearray, off: int, size: int, value: int, what: str) -> None:
    if off < 0 or off + size > len(mem):
        raise VMError(f"out-of-bounds write: {what}[{off}:{off+size}] of {len(mem)}B")
    mem[off:off + size] = (value & ((1 << (8 * size)) - 1)).to_bytes(size, "little")


def _alu(base: str, width: int, a: int, b: int) -> int:
    if width == 32:
        a, b = u32(a), u32(b)
    if base == "add":
        r = a + b
    elif base == "sub":
        r = a - b
    elif base == "mul":
        r = a * b
    elif base == "div":
        if b == 0:
            raise VMError("division by zero")
        r = a // b
    elif base == "mod":
        if b == 0:
            raise VMError("modulo by zero")
        r = a % b
    elif base == "and":
        r = a & b
    elif base == "or":
        r = a | b
    elif base == "xor":
        r = a ^ b
    elif base == "lsh":
        r = a << (b & (width - 1))
    elif base == "rsh":
        r = a >> (b & (width - 1))
    elif base == "arsh":
        sa = s64(a) if width == 64 else (u32(a) - (1 << 32) if u32(a) >= (1 << 31) else u32(a))
        r = sa >> (b & (width - 1))
    elif base == "mov":
        r = b
    elif base == "neg":
        r = -a
    else:
        raise VMError(f"bad ALU base {base}")
    return u64(r) if width == 64 else u32(r)


def _cmp(base: str, a, b) -> bool:
    # Pointer comparisons: only eq/ne against 0 (null) or same-region ptrs.
    if isinstance(a, Ptr) or isinstance(b, Ptr):
        av = 0 if (isinstance(a, int) and a == 0) else a
        bv = 0 if (isinstance(b, int) and b == 0) else b
        if base == "jeq":
            return (av == 0 and bv == 0) if not (isinstance(av, Ptr) and isinstance(bv, Ptr)) \
                else (av.mem is bv.mem and av.off == bv.off)
        if base == "jne":
            return not _cmp("jeq", a, b)
        raise VMError(f"illegal pointer comparison {base}")
    ua, ub = u64(a), u64(b)
    sa, sb = s64(a), s64(b)
    return {
        "jeq": ua == ub, "jne": ua != ub,
        "jgt": ua > ub, "jge": ua >= ub, "jlt": ua < ub, "jle": ua <= ub,
        "jsgt": sa > sb, "jsge": sa >= sb, "jslt": sa < sb, "jsle": sa <= sb,
        "jset": (ua & ub) != 0,
    }[base]


class VM:
    """Interprets one program against a ctx buffer and resolved maps."""

    CALL_DEPTH_LIMIT = 8   # frames, kernel MAX_CALL_FRAMES

    def __init__(self, insns: List[Insn], resolved_maps: Dict[str, BpfMap],
                 *, printk: Optional[Callable[[int], None]] = None,
                 fuel: Optional[int] = None, subprogs=()):
        """``fuel`` caps dynamic instruction count.  The runtime passes the
        verifier's proven step bound here so that even with bounded loops
        accepted statically, the interpreter keeps a runtime
        defense-in-depth: a bug in the bound proof (or a hand-run
        unverified program) trips the fuel check instead of spinning.
        ``subprogs`` are the program's ``call_fn`` callees (SubProgram
        sequence); each activation runs in a fresh frame."""
        self.insns = insns
        self.maps = resolved_maps
        self.printk = printk or (lambda v: None)
        self.fuel = INSN_BUDGET if fuel is None else max(1, int(fuel))
        self.subprogs = tuple(subprogs)

    def run(self, ctx_buf: bytearray) -> int:
        regs: List[object] = [0] * 11
        stack = bytearray(STACK_SIZE)
        regs[1] = Ptr("ctx", ctx_buf, 0)
        regs[FP_REG] = Ptr("stack", stack, STACK_SIZE)
        # fuel is shared across every frame of the call tree (one global
        # dynamic budget, kernel-style), so the counter travels by cell
        return self._exec(self.insns, regs, stack, [0], 1)

    def _exec(self, insns: List[Insn], regs: List[object],
              stack: bytearray, steps: List[int], depth: int) -> int:
        pc = 0
        fuel = self.fuel
        n = len(insns)
        while True:
            steps[0] += 1
            if steps[0] > fuel:
                raise VMError(
                    f"instruction budget exceeded ({fuel} steps): runaway "
                    "loop (verifier bound violated or unverified program)")
            if not (0 <= pc < n):
                raise VMError(f"pc {pc} out of program bounds")
            insn = insns[pc]
            op = insn.op
            if op == "exit":
                r0 = regs[0]
                if isinstance(r0, Ptr):
                    raise VMError("exit with pointer in r0")
                return u64(r0)
            if op == "ja":
                pc += 1 + insn.off
                continue
            if op == "lddw":
                regs[insn.dst] = u64(insn.imm)
                pc += 1
                continue
            if op == "ldmap":
                regs[insn.dst] = Ptr("map", self.maps[insn.map_name], 0)
                pc += 1
                continue
            if op == "call":
                self._call(insn.imm, regs, stack)
                pc += 1
                continue
            if op == "call_fn":
                if not (0 <= insn.imm < len(self.subprogs)):
                    raise VMError(f"call_fn fn{insn.imm} out of range")
                if depth >= self.CALL_DEPTH_LIMIT:
                    raise VMError(
                        f"call depth exceeds {self.CALL_DEPTH_LIMIT} frames")
                sp = self.subprogs[insn.imm]
                _faults.fire("call_fn", sp.name)
                # fresh frame: args r1..r5 copy in, r6..r9 zero-init,
                # own 512-byte stack; only r0 flows back
                cstack = bytearray(STACK_SIZE)
                cregs: List[object] = [0] * 11
                for r in (1, 2, 3, 4, 5):
                    cregs[r] = regs[r]
                cregs[FP_REG] = Ptr("stack", cstack, STACK_SIZE)
                regs[0] = self._exec(list(sp.insns), cregs, cstack,
                                     steps, depth + 1)
                for r in (1, 2, 3, 4, 5):
                    regs[r] = 0   # caller-saved, like helper calls
                pc += 1
                continue
            if is_alu(op):
                width = alu_width(op)
                base = alu_base(op)
                a = regs[insn.dst]
                b = insn.imm if is_imm_form(op) else regs[insn.src]
                if base == "neg":
                    b = 0
                # pointer arithmetic: ptr +/- scalar allowed
                if isinstance(a, Ptr) or isinstance(b, Ptr):
                    regs[insn.dst] = self._ptr_alu(base, width, a, b)
                else:
                    if insn.dst == FP_REG:
                        raise VMError("write to frame pointer r10")
                    regs[insn.dst] = _alu(base, width, int(a), int(b))
                pc += 1
                continue
            if is_jump_cond(op):
                a = regs[insn.dst]
                b = insn.imm if is_imm_form(op) else regs[insn.src]
                pc += 1 + (insn.off if _cmp(jump_base(op), a, b) else 0)
                continue
            if is_load(op):
                p = regs[insn.src]
                if not isinstance(p, Ptr):
                    raise VMError(f"load via non-pointer r{insn.src} (null/scalar deref)")
                if p.kind == "map":
                    raise VMError("load through raw map pointer")
                regs[insn.dst] = _load(p.mem if p.kind != "ctx" else p.mem,
                                       p.off + insn.off, mem_size(op), p.kind)
                pc += 1
                continue
            if is_store(op):
                p = regs[insn.dst]
                if not isinstance(p, Ptr):
                    raise VMError(f"store via non-pointer r{insn.dst} (null/scalar deref)")
                if p.kind == "map":
                    raise VMError("store through raw map pointer")
                val = insn.imm if op.startswith("st") and not op.startswith("stx") \
                    else regs[insn.src]
                if isinstance(val, Ptr):
                    if p.kind != "stack":
                        raise VMError("pointer spill outside stack")
                    # spill: store the Ptr object in a side table keyed by slot
                    raise VMError("pointer spill unsupported in interpreter tier")
                _store(p.mem, p.off + insn.off, mem_size(op), int(val), p.kind)
                if p.kind == "mapval" and p.owner is not None:
                    p.owner.touch()   # version-tracked for bridge caches
                pc += 1
                continue
            raise VMError(f"unhandled opcode {op}")

    def _ptr_alu(self, base: str, width: int, a, b):
        if width != 64:
            raise VMError("32-bit pointer arithmetic")
        if base == "mov":
            return b
        if base == "add":
            if isinstance(a, Ptr) and isinstance(b, int):
                return a + s64(b)
            if isinstance(b, Ptr) and isinstance(a, int):
                return b + s64(a)
        if base == "sub" and isinstance(a, Ptr) and isinstance(b, int):
            return a + (-s64(b))
        if base == "sub" and isinstance(a, Ptr) and isinstance(b, Ptr) \
                and a.mem is b.mem:
            return u64(a.off - b.off)
        raise VMError(f"illegal pointer arithmetic {base}")

    # -- helper dispatch ----------------------------------------------------
    def _call(self, hid: int, regs: List[object], stack: bytearray) -> None:
        h = H.HELPERS.get(hid)
        if h is None:
            raise VMError(f"unknown helper id {hid}")
        _faults.fire("helper", h.name)

        def stack_bytes(p: object, size: int) -> bytes:
            if not isinstance(p, Ptr) or p.kind != "stack":
                raise VMError(f"{h.name}: argument must be a stack pointer")
            if p.off < 0 or p.off + size > STACK_SIZE:
                raise VMError(f"{h.name}: stack buffer out of bounds")
            return bytes(p.mem[p.off:p.off + size])

        if h.name == "map_lookup_elem":
            mp, kp = regs[1], regs[2]
            if not (isinstance(mp, Ptr) and mp.kind == "map"):
                raise VMError("map_lookup_elem: r1 must be a map pointer")
            m: BpfMap = mp.mem
            key = stack_bytes(kp, m.key_size)
            # live view: the program dereferences the returned pointer
            # (kernel semantics); host-side readers get copies instead
            v = m.lookup_ref(key)
            regs[0] = 0 if v is None else Ptr("mapval", v, 0, m)
        elif h.name == "map_update_elem":
            mp, kp, vp = regs[1], regs[2], regs[3]
            if not (isinstance(mp, Ptr) and mp.kind == "map"):
                raise VMError("map_update_elem: r1 must be a map pointer")
            m = mp.mem
            key = stack_bytes(kp, m.key_size)
            if isinstance(vp, Ptr) and vp.kind == "mapval":
                value = bytes(vp.mem[vp.off:vp.off + m.value_size])
            else:
                value = stack_bytes(vp, m.value_size)
            if m.kind == "hash":
                _faults.fire("hash_rmw", m.name)
            regs[0] = u64(m.update(key, value))
        elif h.name == "map_delete_elem":
            mp, kp = regs[1], regs[2]
            m = mp.mem if isinstance(mp, Ptr) else None
            if m is None or mp.kind != "map":
                raise VMError("map_delete_elem: r1 must be a map pointer")
            regs[0] = u64(m.delete(stack_bytes(kp, m.key_size)))
        elif h.name == "ktime_get_ns":
            regs[0] = u64(H.ktime_get_ns())
        elif h.name == "get_prandom_u32":
            regs[0] = H.get_prandom_u32()
        elif h.name == "trace_printk":
            self.printk(int(regs[1]) if not isinstance(regs[1], Ptr) else -1)
            regs[0] = 0
        elif h.name == "ringbuf_reserve":
            mp = regs[1]
            if not (isinstance(mp, Ptr) and mp.kind == "map"):
                raise VMError("ringbuf_reserve: r1 must be a map pointer")
            m = mp.mem
            if not hasattr(m, "reserve_ref"):
                raise VMError(f"ringbuf_reserve on non-ringbuf map {m.name}")
            v = m.reserve_ref()
            regs[0] = 0 if v is None else Ptr("mapval", v, 0, m)
        elif h.name == "ringbuf_submit":
            mp = regs[1]
            if not (isinstance(mp, Ptr) and mp.kind == "map"):
                raise VMError("ringbuf_submit: r1 must be a map pointer")
            regs[0] = u64(mp.mem.submit())
        elif h.name == "ringbuf_discard":
            mp = regs[1]
            if not (isinstance(mp, Ptr) and mp.kind == "map"):
                raise VMError("ringbuf_discard: r1 must be a map pointer")
            regs[0] = u64(mp.mem.discard())
        elif h.name == "ema_update":
            mp, kp, sample, weight = regs[1], regs[2], regs[3], regs[4]
            if not (isinstance(mp, Ptr) and mp.kind == "map"):
                raise VMError("ema_update: r1 must be a map pointer")
            m = mp.mem
            key = stack_bytes(kp, m.key_size)
            _faults.fire("map_rmw", m.name)
            if m.kind == "hash":
                _faults.fire("hash_rmw", m.name)
            w = max(1, int(weight) if not isinstance(weight, Ptr) else 1)
            # the read-modify-write must hold the map lock or a racing
            # update_u64/update loses its write between our read and store
            with m.lock:
                v = m.lookup_ref(key)
                old = 0 if v is None else int.from_bytes(v[0:8], "little")
                new = (old * (w - 1) + int(sample)) // w
                if v is None:
                    buf = bytearray(m.value_size)
                    buf[0:8] = u64(new).to_bytes(8, "little")
                    m.update(key, bytes(buf))
                else:
                    v[0:8] = u64(new).to_bytes(8, "little")
                    m.touch()   # version-tracked for device-bridge caches
            regs[0] = u64(new)
        else:
            raise VMError(f"helper {h.name} not implemented")
        # caller-saved regs are clobbered (kernel semantics)
        for r in (1, 2, 3, 4, 5):
            regs[r] = 0
