"""lower32 — the 32-bit-pair lowering of the u64 policy machine.

Mosaic (the TPU Pallas backend) has no native 64-bit integer ops, so the
uint64 lowering in :mod:`repro.core.jaxc` only compiles on real TPUs via
x64 emulation or interpret mode.  This module re-represents EVERY u64
machine value — registers, stack slots, ctx fields, array-map slots, the
return value — as a ``(lo, hi)`` pair of uint32, with the full u64
semantics synthesized from 32-bit ops:

  * add/sub carry/borrow chains (``lo`` wraps, the carry feeds ``hi``),
  * widening multiply from 16-bit limbs (the classic mulhi synthesis —
    every partial product and carry provably fits uint32),
  * pair shifts/rotates split into the in-lane (< 32) and cross-lane
    (>= 32) half-planes with all shift amounts clamped to [0, 31] so no
    lane ever sees an out-of-range shift,
  * 64-bit div/mod as a 64-step shift-subtract long division (statically
    unrolled; the verifier proves divisors non-zero, zero is defensively
    treated as one exactly like the uint64 tier),
  * pairwise compare chains for every signed/unsigned jump condition
    (hi decides, lo breaks ties — lo compares stay unsigned even for
    signed conditions).

The control-flow machinery (predicated regions, ``lax.fori_loop`` loop
carries, exit-predicate routing) is inherited unchanged from
:class:`repro.core.jaxc._Lowerer`; only the representation hooks are
overridden.  Loads verify exactly once: ``compile_jax32`` reuses the same
``verify_with_info`` artifacts as every other tier.

Array layout convention (host <-> device, little-endian friendly):
the trailing axis holds ``[lo, hi]`` — a uint64 array viewed as ``<u4``
yields exactly this layout, so host conversion is a reinterpret, not a
shuffle.  ctx is uint32[n_fields, 2], array maps are
uint32[max_entries, value_slots, 2], the return value is uint32[2].

None of this path touches the x64 scope: it traces, jits, and executes
with jax's default 32-bit types enabled only.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from .isa import FP_REG, STACK_SIZE, Insn, mem_size
from .jaxc import (JaxcError, _CTX_TAG, _Lowerer, _STACK_TAG, _map_tag,
                   check_supported)
from .maps import BpfMap
from .program import Program
from .verifier import verify_with_info

M64 = (1 << 64) - 1
M32 = 0xFFFFFFFF

Pair = Tuple[jnp.ndarray, jnp.ndarray]  # (lo, hi), both uint32


# ---------------------------------------------------------------------------
# Pair primitives — u64 semantics from uint32 lanes
# ---------------------------------------------------------------------------

def _u32(x) -> jnp.ndarray:
    return jnp.uint32(x & M32)


def pair_const(v: int) -> Pair:
    v &= M64
    return (_u32(v), _u32(v >> 32))


def pair_select(p, a: Pair, b: Pair) -> Pair:
    return (jnp.where(p, a[0], b[0]), jnp.where(p, a[1], b[1]))


def pair_add(a: Pair, b: Pair) -> Pair:
    lo = a[0] + b[0]
    carry = (lo < a[0]).astype(jnp.uint32)
    return (lo, a[1] + b[1] + carry)


def pair_sub(a: Pair, b: Pair) -> Pair:
    lo = a[0] - b[0]
    borrow = (a[0] < b[0]).astype(jnp.uint32)
    return (lo, a[1] - b[1] - borrow)


def mul32_wide(a, b) -> Pair:
    """uint32 x uint32 -> full 64-bit product as (lo, hi).

    16-bit-limb schoolbook multiply; the carry accumulator ``t`` is at
    most ``0xFFFF + 2*0xFFFF`` and the hi sum equals the true high word
    (< 2**32), so nothing wraps."""
    a0, a1 = a & 0xFFFF, a >> 16
    b0, b1 = b & 0xFFFF, b >> 16
    p00, p01 = a0 * b0, a0 * b1
    p10, p11 = a1 * b0, a1 * b1
    t = (p00 >> 16) + (p01 & 0xFFFF) + (p10 & 0xFFFF)
    lo = (t << 16) | (p00 & 0xFFFF)
    hi = p11 + (p01 >> 16) + (p10 >> 16) + (t >> 16)
    return (lo, hi)


def pair_mul(a: Pair, b: Pair) -> Pair:
    """a * b mod 2**64: lo64(a_lo*b_lo) + ((a_lo*b_hi + a_hi*b_lo) << 32)."""
    lo, hi00 = mul32_wide(a[0], b[0])
    return (lo, hi00 + a[0] * b[1] + a[1] * b[0])


def pair_lsh(a: Pair, b: Pair) -> Pair:
    lo, hi = a
    s = b[0] & 63
    s31 = s & 31
    cross = (32 - s31) & 31          # 0 exactly when s31 == 0 (discarded)
    lo_small = lo << s31
    hi_small = (hi << s31) | jnp.where(s31 == 0, jnp.uint32(0), lo >> cross)
    big = s >= 32                    # then s - 32 == s31
    return (jnp.where(big, jnp.uint32(0), lo_small),
            jnp.where(big, lo << s31, hi_small))


def pair_rsh(a: Pair, b: Pair) -> Pair:
    lo, hi = a
    s = b[0] & 63
    s31 = s & 31
    cross = (32 - s31) & 31
    lo_small = (lo >> s31) | jnp.where(s31 == 0, jnp.uint32(0), hi << cross)
    hi_small = hi >> s31
    big = s >= 32
    return (jnp.where(big, hi >> s31, lo_small),
            jnp.where(big, jnp.uint32(0), hi_small))


def pair_arsh(a: Pair, b: Pair) -> Pair:
    lo, hi = a
    shi = hi.astype(jnp.int32)
    s = b[0] & 63
    s31 = s & 31
    s31i = s31.astype(jnp.int32)
    cross = (32 - s31) & 31
    lo_small = (lo >> s31) | jnp.where(s31 == 0, jnp.uint32(0), hi << cross)
    hi_small = (shi >> s31i).astype(jnp.uint32)
    sign_fill = (shi >> 31).astype(jnp.uint32)
    big = s >= 32
    return (jnp.where(big, (shi >> s31i).astype(jnp.uint32), lo_small),
            jnp.where(big, sign_fill, hi_small))


def pair_cmp(base: str, a: Pair, b: Pair):
    """Every jump condition as a pairwise compare chain: the hi lane
    decides (signed for js* — only hi carries the sign), equal-hi ties
    break on an UNSIGNED lo compare in both cases."""
    al, ah = a
    bl, bh = b
    if base == "jeq":
        return jnp.logical_and(ah == bh, al == bl)
    if base == "jne":
        return jnp.logical_not(jnp.logical_and(ah == bh, al == bl))
    if base == "jset":
        return ((ah & bh) | (al & bl)) != 0
    hi_eq = ah == bh
    signed = base in ("jsgt", "jsge", "jslt", "jsle")
    ha = ah.astype(jnp.int32) if signed else ah
    hb = bh.astype(jnp.int32) if signed else bh
    if base in ("jgt", "jsgt"):
        return (ha > hb) | (hi_eq & (al > bl))
    if base in ("jge", "jsge"):
        return (ha > hb) | (hi_eq & (al >= bl))
    if base in ("jlt", "jslt"):
        return (ha < hb) | (hi_eq & (al < bl))
    if base in ("jle", "jsle"):
        return (ha < hb) | (hi_eq & (al <= bl))
    raise JaxcError(f"compare base {base}")


def pair_divmod(a: Pair, b: Pair) -> Tuple[Pair, Pair]:
    """(a // b, a % b) by 64-step shift-subtract long division.

    The step index is static, so every per-bit shift amount is a
    compile-time constant in [0, 31] — nothing here needs a 64-bit lane.
    b == 0 is defensively treated as 1 (matching the uint64 tier; the
    verifier proves policy divisors non-zero)."""
    bz = jnp.logical_and(b[0] == 0, b[1] == 0)
    b = pair_select(bz, pair_const(1), b)
    q_lo = q_hi = jnp.uint32(0)
    r: Pair = pair_const(0)
    for i in range(63, -1, -1):
        bit = (a[1] >> (i - 32)) & 1 if i >= 32 else (a[0] >> i) & 1
        r = ((r[0] << 1) | bit, (r[1] << 1) | (r[0] >> 31))
        ge = pair_cmp("jge", r, b)
        r = pair_select(ge, pair_sub(r, b), r)
        g = ge.astype(jnp.uint32)
        if i >= 32:
            q_hi = q_hi | (g << (i - 32))
        else:
            q_lo = q_lo | (g << i)
    return (q_lo, q_hi), r


def _alu64_pair(base: str, a: Pair, b: Pair) -> Pair:
    if base == "mov":
        return b
    if base == "add":
        return pair_add(a, b)
    if base == "sub":
        return pair_sub(a, b)
    if base == "mul":
        return pair_mul(a, b)
    if base == "div":
        return pair_divmod(a, b)[0]
    if base == "mod":
        return pair_divmod(a, b)[1]
    if base == "and":
        return (a[0] & b[0], a[1] & b[1])
    if base == "or":
        return (a[0] | b[0], a[1] | b[1])
    if base == "xor":
        return (a[0] ^ b[0], a[1] ^ b[1])
    if base == "lsh":
        return pair_lsh(a, b)
    if base == "rsh":
        return pair_rsh(a, b)
    if base == "arsh":
        return pair_arsh(a, b)
    if base == "neg":
        return pair_sub(pair_const(0), a)
    raise JaxcError(f"ALU base {base}")


def _alu32_pair(base: str, a: Pair, b: Pair) -> Pair:
    """eBPF 32-bit ALU: operate on the lo lanes, zero the hi lane."""
    al, bl = a[0], b[0]
    z = jnp.uint32(0)
    if base == "mov":
        return (bl, z)
    if base == "add":
        return (al + bl, z)
    if base == "sub":
        return (al - bl, z)
    if base == "mul":
        return (al * bl, z)
    if base == "div":
        return (al // jnp.maximum(bl, jnp.uint32(1)), z)
    if base == "mod":
        return (al % jnp.maximum(bl, jnp.uint32(1)), z)
    if base == "and":
        return (al & bl, z)
    if base == "or":
        return (al | bl, z)
    if base == "xor":
        return (al ^ bl, z)
    if base == "lsh":
        return (al << (bl & 31), z)
    if base == "rsh":
        return (al >> (bl & 31), z)
    if base == "arsh":
        return ((al.astype(jnp.int32)
                 >> (bl & 31).astype(jnp.int32)).astype(jnp.uint32), z)
    if base == "neg":
        return (z - al, z)
    raise JaxcError(f"ALU base {base}")


# ---------------------------------------------------------------------------
# The lowerer: jaxc's CFG walk over the pair representation
# ---------------------------------------------------------------------------

class _Lowerer32(_Lowerer):
    """`_Lowerer` with every machine value as a (lo, hi) uint32 pair.

    Inherits the region/loop machinery verbatim — the snapshot/restore
    loop carries thread tuples of pairs through ``lax.fori_loop`` exactly
    like tuples of uint64 scalars."""

    # ---- representation hooks -------------------------------------------
    def _init_state(self, ctx_vec, map_arrays) -> None:
        self.ctx = jnp.asarray(ctx_vec, jnp.uint32)          # [fields, 2]
        self.maps = {k: jnp.asarray(v, jnp.uint32)           # [n, slots, 2]
                     for k, v in map_arrays.items()}
        self.regs = [pair_const(0)] * 11
        self.regs[1] = pair_const(_CTX_TAG)
        self.regs[FP_REG] = pair_const(_STACK_TAG | STACK_SIZE)
        self.stack = self._fresh_stack()
        self.done = jnp.asarray(False)
        self.ret = pair_const(0)

    def _fresh_stack(self):
        return jnp.zeros((STACK_SIZE // 8, 2), jnp.uint32)

    def _imm(self, imm: int) -> Pair:
        return pair_const(imm)

    def _coerce(self, val) -> Pair:
        if not (isinstance(val, tuple) and len(val) == 2):
            raise JaxcError("pair lowering produced a non-pair value")
        return val

    def _sel(self, p, new: Pair, old: Pair) -> Pair:
        return pair_select(p, new, old)

    def _alu(self, base: str, width: int, a: Pair, b: Pair) -> Pair:
        return _alu64_pair(base, a, b) if width == 64 \
            else _alu32_pair(base, a, b)

    def _cmp(self, base: str, a: Pair, b: Pair):
        return pair_cmp(base, a, b)

    # ---- memory ----------------------------------------------------------
    def _stack_load(self, ptr: Pair, size: int):
        slot = (ptr[0] >> 3).astype(jnp.int32)   # lo lane holds the offset
        word: Pair = (self.stack[slot, 0], self.stack[slot, 1])
        if size == 8:
            return word
        sh = (ptr[0] & 7) * 8
        shifted = pair_rsh(word, (sh, jnp.uint32(0)))
        return (shifted[0] & _u32((1 << (8 * size)) - 1), jnp.uint32(0))

    def _stack_store(self, P, ptr: Pair, size: int, val: Pair) -> None:
        slot = (ptr[0] >> 3).astype(jnp.int32)
        word: Pair = (self.stack[slot, 0], self.stack[slot, 1])
        if size == 8:
            new = val
        else:
            mask = (1 << (8 * size)) - 1
            sh: Pair = ((ptr[0] & 7) * 8, jnp.uint32(0))
            hole = pair_lsh(pair_const(mask), sh)
            piece = pair_lsh((val[0] & _u32(mask), jnp.uint32(0)), sh)
            new = ((word[0] & ~hole[0]) | piece[0],
                   (word[1] & ~hole[1]) | piece[1])
        sel = pair_select(P, new, word)
        self.stack = self.stack.at[slot].set(jnp.stack([sel[0], sel[1]]))

    @staticmethod
    def _mapval_decode(ptr: Pair):
        lo, hi = ptr
        mi = ((hi >> 24) - 16).astype(jnp.int32)
        key = ((hi << 8) | (lo >> 24)).astype(jnp.int32)
        off = lo & 0xFFFFFF
        return mi, key, off

    def _exec_load(self, pc: int, insn: Insn, P) -> None:
        size = mem_size(insn.op)
        region, mname, base = self.fninfo.mem_info[pc]
        ptr = pair_add(self.regs[insn.src], pair_const(insn.off & M64))
        if region == "ctx":
            off = base + insn.off            # static (verified)
            val: Pair = (self.ctx[off // 8, 0], self.ctx[off // 8, 1])
            if size < 8:
                val = (val[0] & _u32((1 << (8 * size)) - 1), jnp.uint32(0))
        elif region == "stack":
            val = self._stack_load(ptr, size)
        else:  # mapval
            _, key, off = self._mapval_decode(ptr)
            slot = (off >> 3).astype(jnp.int32)
            val = (self.maps[mname][key, slot, 0],
                   self.maps[mname][key, slot, 1])
            if size < 8:
                val = (val[0] & _u32((1 << (8 * size)) - 1), jnp.uint32(0))
        self._wreg(P, insn.dst, val)

    def _exec_store(self, pc: int, insn: Insn, P) -> None:
        size = mem_size(insn.op)
        region, mname, base = self.fninfo.mem_info[pc]
        val: Pair = pair_const(insn.imm & M64) \
            if not insn.op.startswith("stx") else self.regs[insn.src]
        ptr = pair_add(self.regs[insn.dst], pair_const(insn.off & M64))
        if region == "ctx":
            slot = (base + insn.off) // 8
            old: Pair = (self.ctx[slot, 0], self.ctx[slot, 1])
            sel = pair_select(P, val, old)
            self.ctx = self.ctx.at[slot].set(jnp.stack([sel[0], sel[1]]))
        elif region == "stack":
            self._stack_store(P, ptr, size, val)
        else:  # mapval
            _, key, off = self._mapval_decode(ptr)
            slot = (off >> 3).astype(jnp.int32)
            old = (self.maps[mname][key, slot, 0],
                   self.maps[mname][key, slot, 1])
            sel = pair_select(P, val, old)
            self.maps[mname] = self.maps[mname].at[key, slot].set(
                jnp.stack([sel[0], sel[1]]))

    # ---- helpers ---------------------------------------------------------
    def _call(self, pc: int, insn: Insn, P) -> Pair:
        hid = insn.imm
        mname = self.fninfo.call_map.get(pc)
        if mname is None:
            raise JaxcError(f"helper at insn {pc} has no static map binding")
        mi = self.map_index[mname]
        d = self.decls[mi]
        if d.kind == "ringbuf":
            return self._call_ringbuf32(hid, mi, d, P)
        if d.kind == "hash":
            return self._call_hash32(hid, mi, d, P)
        key = self._stack_load(self.regs[2], d.key_size)   # hi lane is 0
        valid = key[0] < jnp.uint32(d.max_entries)
        ki = jnp.minimum(key[0], jnp.uint32(d.max_entries - 1)).astype(
            jnp.int32)
        if hid == 1:  # map_lookup_elem(map, key*)
            tag = pair_const(_map_tag(mi))
            shifted = pair_lsh(key, pair_const(24))
            enc: Pair = (tag[0] | shifted[0], tag[1] | shifted[1])
            return pair_select(valid, enc, pair_const(0))
        if hid == 2:  # map_update_elem(map, key*, value*, flags)
            n_slots = d.value_size // 8
            rows = [self._stack_load(
                pair_add(self.regs[3], pair_const(8 * s)), 8)
                for s in range(n_slots)]
            newrow = jnp.stack([jnp.stack([lo, hi]) for lo, hi in rows])
            old = self.maps[d.name][ki]
            take = jnp.logical_and(P, valid)
            self.maps[d.name] = self.maps[d.name].at[ki].set(
                jnp.where(take, newrow, old))
            return pair_select(valid, pair_const(0), pair_const(M64))
        if hid == 64:  # ema_update(map, key*, sample, weight)
            one = pair_const(1)
            w = pair_select(pair_cmp("jgt", self.regs[4], one),
                            self.regs[4], one)
            old = (self.maps[d.name][ki, 0, 0], self.maps[d.name][ki, 0, 1])
            acc = pair_add(pair_mul(old, pair_sub(w, one)), self.regs[3])
            new = pair_divmod(acc, w)[0]
            take = jnp.logical_and(P, valid)
            sel = pair_select(take, new, old)
            self.maps[d.name] = self.maps[d.name].at[ki, 0].set(
                jnp.stack([sel[0], sel[1]]))
            return new
        raise JaxcError(f"helper {hid} not supported in-graph")

    def _call_hash32(self, hid: int, mi: int, d, P) -> Pair:
        """Pair-form open-addressing probe (see ``_Lowerer._call_hash``
        for the layout and termination argument).  ``hash_slot`` folds
        the key to 32 bits (``lo ^ hi``), so locating the probe origin
        costs one uint32 modulo — no pair division anywhere on the scan,
        and key equality is a two-lane compare."""
        arr = self.maps[d.name]
        slots = d.value_size // 8
        kcol, ucol = slots, slots + 1
        cap = d.max_entries
        key = self._stack_load(self.regs[2], d.key_size)   # Pair
        keys_lo = arr[:cap, kcol, 0]
        keys_hi = arr[:cap, kcol, 1]
        used = (arr[:cap, ucol, 0] | arr[:cap, ucol, 1]) > 0
        h = (key[0] ^ key[1]) % jnp.uint32(cap)
        dist = (jnp.arange(cap, dtype=jnp.uint32) - h) % jnp.uint32(cap)
        is_match = used & (keys_lo == key[0]) & (keys_hi == key[1])
        stop = is_match | jnp.logical_not(used)
        first = jnp.argmin(
            jnp.where(stop, dist, jnp.uint32(cap))).astype(jnp.int32)
        has_stop = jnp.any(stop)
        hit = jnp.logical_and(has_stop, is_match[first])
        can_claim = jnp.logical_and(has_stop, jnp.logical_not(hit))
        if hid == 1:  # map_lookup_elem: encode the physical row index
            tag = pair_const(_map_tag(mi))
            row: Pair = (first.astype(jnp.uint32), jnp.uint32(0))
            sh = pair_lsh(row, pair_const(24))
            enc: Pair = (tag[0] | sh[0], tag[1] | sh[1])
            return pair_select(hit, enc, pair_const(0))
        ok = jnp.logical_or(hit, can_claim)
        oldrow = lax.dynamic_slice(
            arr, (first, jnp.int32(0), jnp.int32(0)),
            (1, arr.shape[1], 2))[0]
        if hid == 2:  # map_update_elem: overwrite hit else claim a slot
            vals = [self._stack_load(
                pair_add(self.regs[3], pair_const(8 * s)), 8)
                for s in range(slots)]
            newvals = jnp.stack([jnp.stack([lo, hi]) for lo, hi in vals])
            ret = pair_select(ok, pair_const(0), pair_const(M64))
        elif hid == 64:  # ema_update: RMW slot 0 (miss seeds from old=0)
            one = pair_const(1)
            w = pair_select(pair_cmp("jgt", self.regs[4], one),
                            self.regs[4], one)
            old: Pair = (jnp.where(hit, oldrow[0, 0], jnp.uint32(0)),
                         jnp.where(hit, oldrow[0, 1], jnp.uint32(0)))
            acc = pair_add(pair_mul(old, pair_sub(w, one)), self.regs[3])
            new = pair_divmod(acc, w)[0]
            keep = jnp.where(hit, oldrow[:slots],
                             jnp.zeros((slots, 2), jnp.uint32))
            newvals = keep.at[0].set(jnp.stack([new[0], new[1]]))
            ret = new
        else:
            raise JaxcError(f"helper {hid} on hash map '{d.name}'")
        take = jnp.logical_and(P, ok)
        tail = jnp.stack([jnp.stack([key[0], key[1]]),
                          jnp.stack([jnp.uint32(1), jnp.uint32(0)])])
        full_new = jnp.concatenate([newvals, tail])
        sel = jnp.where(take, full_new, oldrow)
        arr = lax.dynamic_update_slice(
            arr, sel[None], (first, jnp.int32(0), jnp.int32(0)))
        occ: Pair = (arr[cap, 0, 0], arr[cap, 0, 1])
        occ1 = pair_select(jnp.logical_and(P, can_claim),
                           pair_add(occ, pair_const(1)), occ)
        arr = arr.at[cap, 0].set(jnp.stack([occ1[0], occ1[1]]))
        self.maps[d.name] = arr
        return ret

    def _call_ringbuf32(self, hid: int, mi: int, d, P) -> Pair:
        """reserve/submit/discard over the device layout's control words,
        with the free-running u64 cursors held as (lo, hi) pairs — the
        carry chains keep cursor arithmetic exact past 2**32 events."""
        arr = self.maps[d.name]
        slots = d.value_size // 8
        ctl = lambda w: (d.max_entries + w // slots, w % slots)  # noqa: E731
        (hr, hc), (pr, pc2) = ctl(0), ctl(3)
        head: Pair = (arr[hr, hc, 0], arr[hr, hc, 1])
        pend: Pair = (arr[pr, pc2, 0], arr[pr, pc2, 1])

        def put(r, c, pair: Pair) -> None:
            self.maps[d.name] = self.maps[d.name].at[r, c].set(
                jnp.stack([pair[0], pair[1]]))

        if hid == 66:  # ringbuf_submit
            head2 = pair_add(head, pend)
            put(hr, hc, pair_select(P, head2, head))
            put(pr, pc2, pair_select(P, pair_const(0), pend))
            return pair_const(0)
        if hid == 67:  # ringbuf_discard
            put(pr, pc2, pair_select(P, pair_const(0), pend))
            return pair_const(0)
        if hid != 65:
            raise JaxcError(f"helper {hid} on ringbuf map '{d.name}'")
        # ringbuf_reserve: implicit commit, then NULL (+1 drop) on full
        (tr, tc), (dr, dc) = ctl(1), ctl(2)
        tail: Pair = (arr[tr, tc, 0], arr[tr, tc, 1])
        drops: Pair = (arr[dr, dc, 0], arr[dr, dc, 1])
        head1 = pair_add(head, pend)
        full = pair_cmp("jge", pair_sub(head1, tail),
                        pair_const(d.max_entries))
        put(hr, hc, pair_select(P, head1, head))
        put(pr, pc2, pair_select(
            P, pair_select(full, pair_const(0), pair_const(1)), pend))
        put(dr, dc, pair_select(jnp.logical_and(P, full),
                                pair_add(drops, pair_const(1)), drops))
        row = pair_divmod(head1, pair_const(d.max_entries))[1]
        tag = pair_const(_map_tag(mi))
        sh = pair_lsh(row, pair_const(24))
        enc: Pair = (tag[0] | sh[0], tag[1] | sh[1])
        return pair_select(full, pair_const(0), enc)


def compile_jax32(prog: Program, vinfo=None):
    """Return (fn, map_names) in the pair calling convention.

    ``fn(ctx_vec32, map_arrays32) -> (ret32, ctx32_out, map_arrays32_out)``
    where ``ctx_vec32`` is uint32[n_fields, 2], each map array is
    uint32[max_entries, value_slots, 2] (trailing axis = [lo, hi]) and
    ``ret32`` is uint32[2].  Pure and jit-safe; runs with jax's default
    32-bit types — no x64 scope anywhere.

    ``vinfo`` reuses a prior :func:`verify_with_info` result so the
    runtime's load path verifies exactly once across every tier."""
    check_supported(prog)
    for d in prog.maps:
        if d.kind == "lru_hash":
            raise JaxcError(
                f"map '{d.name}' is lru_hash; the 32-bit-pair tier does "
                "not lower LRU maps (pair-compare scans over recency "
                "dominate the kernel) — use the pallas/jaxc or host tiers")
    if vinfo is None:
        vinfo = verify_with_info(prog)

    def run(ctx_vec32, map_arrays32: Dict[str, jnp.ndarray]):
        ret, ctx, maps = _Lowerer32(prog, vinfo, ctx_vec32,
                                    map_arrays32).run()
        return jnp.stack([ret[0], ret[1]]), ctx, maps

    return run, [d.name for d in prog.maps]


# ---------------------------------------------------------------------------
# Host <-> device conversion (pure numpy reinterprets — no x64 scope)
# ---------------------------------------------------------------------------

def map_to_array32(m: BpfMap) -> jnp.ndarray:
    """Host map -> uint32[rows, cols, 2]; a ``<u4`` view of the map's
    little-endian u64 device image (``to_device``), so [..., 0] is lo and
    [..., 1] is hi.  Control/metadata rows ride along untranslated."""
    from .maps import MapError
    try:
        a64 = m.to_device()
    except MapError as e:
        raise JaxcError(str(e)) from None
    rows, cols = a64.shape
    return jnp.asarray(
        np.ascontiguousarray(a64).view("<u4").reshape(rows, cols, 2))


def array32_to_map(arr, m: BpfMap) -> None:
    """Write pair-form device map state back into the host map."""
    host = np.ascontiguousarray(np.asarray(arr, dtype=np.uint32))
    m.from_device(host.view("<u8").reshape(host.shape[0], host.shape[1]))


def ctx_to_vec32(ctx_buf: bytearray) -> jnp.ndarray:
    return jnp.asarray(
        np.frombuffer(bytes(ctx_buf), dtype="<u4").reshape(-1, 2))


def vec32_to_bytes(arr) -> bytes:
    return np.asarray(arr).astype("<u4").tobytes()


def ret32_to_int(ret) -> int:
    r = np.asarray(ret)
    return int(r[0]) | (int(r[1]) << 32)
