"""repro.core — verified, composable policy execution (the paper's contribution).

Layers:
  isa / asm / frontend   — bytecode, assembler, restricted-Python compiler
  verifier               — PREVAIL-style load-time static verification
  vm / jit               — interpreter (oracle), specializing host JIT
  jaxc / pallasc         — in-graph tiers: pure-JAX if-conversion, and the
                           single-Pallas-kernel lowering (zero host cost)
  maps                   — typed cross-plugin state (composability substrate)
  runtime                — load/attach/hot-reload lifecycle, tier selection,
                           per-link circuit breakers
  faults                 — deterministic fault injection at trust boundaries
"""

from .asm import AsmError, assemble
from .context import (Algo, AxisKind, CollType, PolicyContextValues,
                      ProfEvent, Proto, make_ctx)
from .faults import FaultInjector, InjectedFault
from .frontend import (CompileError, compile_policy, map_decl, policy,
                       subroutine)
from .isa import Insn
from .maps import ArrayMap, BpfMap, HashMap, MapRegistry, PerCpuArrayMap
from .program import MapDecl, Program
from .runtime import (BreakerConfig, LinkError, LoadedProgram, PolicyLink,
                      PolicyRuntime, global_runtime, reset_global_runtime)
from .verifier import VerifierError, verify
from .vm import VM, VMError

__all__ = [
    "AsmError", "assemble", "Algo", "AxisKind", "CollType",
    "PolicyContextValues", "ProfEvent", "Proto", "make_ctx",
    "FaultInjector", "InjectedFault",
    "CompileError", "compile_policy", "map_decl", "policy",
    "subroutine", "Insn",
    "ArrayMap", "BpfMap", "HashMap", "MapRegistry", "PerCpuArrayMap",
    "MapDecl", "Program", "BreakerConfig", "LinkError", "LoadedProgram",
    "PolicyLink", "PolicyRuntime",
    "global_runtime", "reset_global_runtime", "VerifierError", "verify",
    "VM", "VMError",
]
