"""pallasc — verified policy bytecode lowered to ONE Pallas kernel.

The in-kernel execution tier.  The ladder so far: the interpreter (ground
truth), the host JIT (v1/v2 Python closures), and jaxc (pure-JAX
if-conversion fused into the step program).  jaxc already removed host
round-trips, but its lowering emits free-floating jnp ops that XLA may
schedule anywhere; this tier packages the whole verified decision —
including PR 3's bounded loops — into a single :func:`pl.pallas_call`
kernel with explicit BlockSpec/VMEM tiling, so on-TPU the policy runs as
one fused kernel whose operands (ctx vector + array-map state) are
VMEM-resident for the duration of the decision.  Host marginal cost per
decision is zero: the host neither computes nor copies anything once the
step is dispatched.

Two word widths share the entry point:

  * ``word_width=64`` — the uint64 lowering
    (:class:`repro.core.jaxc._Lowerer`).  Compiles through Mosaic only
    via x64 emulation/interpret mode; needs the scoped x64 context.
  * ``word_width=32`` — the Mosaic-ready pair lowering
    (:class:`repro.core.lower32._Lowerer32`): every u64 register, stack
    slot, ctx field, and map slot is a ``(lo, hi)`` uint32 pair with
    explicit carry/borrow, widening multiply, pair shifts, and pairwise
    compare chains.  No 64-bit integer op ever reaches the kernel, and
    no x64 scope is needed anywhere on the path.

``word_width=None`` picks 64 when the build has a working x64 scope and
falls back to 32 otherwise — builds where ``enable_x64`` is broken can
still run the pallas tier through the pair representation.

Lowering path (shared with jaxc by construction): the verifier's
artifacts — shared CFG, proven ``loop_bounds``, per-insn region info —
drive the same predicated block-by-block lowering; forward regions
if-convert, each natural loop becomes one ``lax.fori_loop`` running
exactly ``bound + 1`` header visits.  ``compile_*(prog, vinfo)`` reuses
the runtime's single verify pass.

Backends: on TPU the kernel compiles through Mosaic; on CPU (CI) the
same ``pallas_call`` runs in interpret mode — identical lowering path,
executed by the Pallas interpreter.  ``mode="jit"`` bypasses the kernel
harness entirely and jits the bare lowering body (the pure-JAX fallback
for builds without a working Pallas).

The host bridge (:class:`DeviceBridge`, returned by
:func:`compile_host`) keeps map state DEVICE-RESIDENT across calls:
uploads are version-gated (a clean host map is never re-uploaded),
writebacks cover only the maps the program can statically write, and
``flush()`` forces a full device->host sync — the runtime calls it at
every T3 boundary (detach / ``link.replace()`` / bundle reload) so host
maps remain the cross-plugin source of truth exactly when attachment
changes hands.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..compat import enable_x64, maybe_x64
from . import faults as _faults
from .context import Algo, Proto
from .jaxc import (JaxcError, _Lowerer, array_to_map, check_supported,
                   compile_jax, ctx_to_vec, map_to_array, written_map_names)
from .lower32 import (_Lowerer32, array32_to_map, compile_jax32,
                      ctx_to_vec32, map_to_array32, ret32_to_int,
                      vec32_to_bytes)
from .maps import BpfMap, device_shape
from .program import Program
from .verifier import verify_with_info

try:  # pallas is present on every jax build we target, but stay graceful
    from jax.experimental import pallas as pl
    HAVE_PALLAS = True
except Exception:  # pragma: no cover — exercised only on exotic builds
    pl = None
    HAVE_PALLAS = False


class PallascError(Exception):
    pass


def _resolve_mode(mode: Optional[str]) -> str:
    if mode is None:
        mode = "pallas" if HAVE_PALLAS else "jit"
    if mode not in ("pallas", "jit"):
        raise PallascError(f"unknown pallasc mode {mode!r}; "
                           "use 'pallas' or 'jit'")
    if mode == "pallas" and not HAVE_PALLAS:
        raise PallascError("this jax build has no importable Pallas; "
                           "use mode='jit' (the pure-JAX fallback)")
    return mode


def _resolve_word_width(word_width: Optional[int]) -> int:
    if word_width is None:
        from ..compat import have_x64
        return 64 if have_x64() else 32
    if word_width not in (32, 64):
        raise PallascError(f"unknown word_width {word_width!r}; use 64 "
                           "(uint64 state, needs x64) or 32 (Mosaic-ready "
                           "(lo, hi) uint32 pairs)")
    return word_width


def compile_pallas(prog: Program, vinfo=None, *, mode: Optional[str] = None,
                   interpret: Optional[bool] = None,
                   word_width: Optional[int] = None):
    """Return (fn, map_names) — the in-graph calling convention.

    With ``word_width=64``: ``fn(ctx_vec, map_arrays) ->
    (ret, ctx_vec_out, map_arrays_out)``, ``ctx_vec`` uint64[n_fields],
    maps uint64[max_entries, value_slots] — requires the x64 scope.

    With ``word_width=32`` (the Mosaic-ready pair form): ``ctx_vec`` is
    uint32[n_fields, 2], maps are uint32[max_entries, value_slots, 2]
    (trailing axis = [lo, hi]), ``ret`` is uint32[2]; no x64 anywhere.

    ``vinfo`` reuses a prior :func:`verify_with_info` result (shared
    cfg / loop_bounds / max_steps / region info) — the runtime's load
    path verifies once and hands the artifacts down.  ``mode=None``
    auto-selects the Pallas kernel when available, the pure-JAX body
    otherwise; ``interpret=None`` compiles through Mosaic on TPU and the
    Pallas interpreter elsewhere (same lowering path either way);
    ``word_width=None`` prefers 64 and falls back to 32 on builds whose
    x64 scope does not work.
    """
    try:
        check_supported(prog)
    except JaxcError as e:
        raise PallascError(
            f"policy '{prog.name}' cannot lower to the pallas tier: {e}"
        ) from e
    if vinfo is None:
        vinfo = verify_with_info(prog)
    mode = _resolve_mode(mode)
    word_width = _resolve_word_width(word_width)
    lru = [d.name for d in prog.maps if d.kind == "lru_hash"]
    if word_width == 32 and lru:
        raise PallascError(
            f"policy '{prog.name}' uses lru_hash map(s) "
            f"{', '.join(repr(n) for n in lru)}; the 32-bit-pair tier does "
            "not lower LRU recency/clock metadata.  Workarounds: declare "
            "the map with kind=\"hash\" (the fixed-capacity open-addressing "
            "table lowers in-graph on every tier, including pallas32 — you "
            "lose eviction, inserts fail with E2BIG when full), keep "
            "word_width=64 (x64 emulation), or run this policy on a host "
            "tier (interp/jit/native), where lru_hash is fully supported")
    names = [d.name for d in prog.maps]

    if mode == "jit":
        # pure-JAX fallback: the identical lowering body, no kernel harness
        if word_width == 32:
            return compile_jax32(prog, vinfo)

        def fn(ctx_vec, map_arrays: Dict[str, jnp.ndarray]):
            with enable_x64(True):
                return _Lowerer(prog, vinfo,
                                jnp.asarray(ctx_vec, jnp.uint64),
                                map_arrays).run()
        return fn, names

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if word_width == 32:
        return _build_pallas_fn32(prog, vinfo, interpret), names
    return _build_pallas_fn(prog, vinfo, interpret), names


def _build_pallas_fn(prog: Program, vinfo, interpret: bool) -> Callable:
    """One ``pl.pallas_call``: ctx + every array map in, (ret, ctx, maps)
    out, all as full-block VMEM tiles (house style: explicit BlockSpecs
    with an index map per operand; grid=(1,) — the whole decision state
    fits one grid step's VMEM by the verifier's bounded-state guarantee:
    ctx is n_fields*8 bytes, maps are bounded by their declarations)."""
    decls = list(prog.maps)
    names = [d.name for d in decls]
    n_maps = len(names)
    n_fields = prog.ctx_type.size // 8

    def kernel(*refs):
        ctx_ref = refs[0]
        map_refs = refs[1:1 + n_maps]
        ret_ref = refs[1 + n_maps]
        ctx_out_ref = refs[2 + n_maps]
        out_map_refs = refs[3 + n_maps:]
        ctx = ctx_ref[...]
        maps = {n: r[...] for n, r in zip(names, map_refs)}
        ret, ctx_out, maps_out = _Lowerer(prog, vinfo, ctx, maps).run()
        ret_ref[...] = jnp.reshape(ret, (1,))
        ctx_out_ref[...] = ctx_out
        for n, r in zip(names, out_map_refs):
            r[...] = maps_out[n]

    vec_spec = pl.BlockSpec((n_fields,), lambda i: (0,))
    # device_shape appends control/metadata rows (ringbuf cursors, LRU
    # key/recency/clock) to the value rows — one rectangular VMEM tile
    # per map regardless of kind
    map_shapes = [device_shape(d.kind, d.value_size, d.max_entries)
                  for d in decls]
    map_specs = [pl.BlockSpec(s, lambda i: (0, 0)) for s in map_shapes]
    call = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[vec_spec] + map_specs,
        out_specs=(pl.BlockSpec((1,), lambda i: (0,)), vec_spec,
                   *map_specs),
        out_shape=(jax.ShapeDtypeStruct((1,), jnp.uint64),
                   jax.ShapeDtypeStruct((n_fields,), jnp.uint64),
                   *[jax.ShapeDtypeStruct(s, jnp.uint64)
                     for s in map_shapes]),
        interpret=interpret,
    )

    def fn(ctx_vec, map_arrays: Dict[str, jnp.ndarray]):
        with enable_x64(True):
            args = [jnp.asarray(ctx_vec, jnp.uint64)]
            args += [jnp.asarray(map_arrays[n], jnp.uint64) for n in names]
            out = call(*args)
            return out[0][0], out[1], dict(zip(names, out[2:]))
    return fn


def _build_pallas_fn32(prog: Program, vinfo, interpret: bool) -> Callable:
    """The pair-form kernel: same harness shape as :func:`_build_pallas_fn`
    but every operand is uint32 with a trailing [lo, hi] axis — the only
    integer width inside the kernel is 32 bits, which is what hardware
    Mosaic can lower natively."""
    decls = list(prog.maps)
    names = [d.name for d in decls]
    n_maps = len(names)
    n_fields = prog.ctx_type.size // 8

    def kernel(*refs):
        ctx_ref = refs[0]
        map_refs = refs[1:1 + n_maps]
        ret_ref = refs[1 + n_maps]
        ctx_out_ref = refs[2 + n_maps]
        out_map_refs = refs[3 + n_maps:]
        ctx = ctx_ref[...]
        maps = {n: r[...] for n, r in zip(names, map_refs)}
        ret, ctx_out, maps_out = _Lowerer32(prog, vinfo, ctx, maps).run()
        ret_ref[...] = jnp.stack([ret[0], ret[1]])
        ctx_out_ref[...] = ctx_out
        for n, r in zip(names, out_map_refs):
            r[...] = maps_out[n]

    vec_spec = pl.BlockSpec((n_fields, 2), lambda i: (0, 0))
    map_shapes = [device_shape(d.kind, d.value_size, d.max_entries)
                  for d in decls]
    map_specs = [pl.BlockSpec((*s, 2), lambda i: (0, 0, 0))
                 for s in map_shapes]
    call = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[vec_spec] + map_specs,
        out_specs=(pl.BlockSpec((2,), lambda i: (0,)), vec_spec,
                   *map_specs),
        out_shape=(jax.ShapeDtypeStruct((2,), jnp.uint32),
                   jax.ShapeDtypeStruct((n_fields, 2), jnp.uint32),
                   *[jax.ShapeDtypeStruct((*s, 2), jnp.uint32)
                     for s in map_shapes]),
        interpret=interpret,
    )

    def fn(ctx_vec32, map_arrays32: Dict[str, jnp.ndarray]):
        args = [jnp.asarray(ctx_vec32, jnp.uint32)]
        args += [jnp.asarray(map_arrays32[n], jnp.uint32) for n in names]
        out = call(*args)
        return out[0], out[1], dict(zip(names, out[2:]))
    return fn


# ---------------------------------------------------------------------------
# Host bridge — the PolicyRuntime load/invoke contract for in-graph tiers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BridgeStats:
    """Introspection counters for the device-resident bridge; the
    regression tests and perf benchmarks key their assertions off these
    (e.g. "N warm repeat calls perform zero map uploads")."""
    calls: int = 0
    map_uploads: int = 0
    map_downloads: int = 0
    flushes: int = 0
    # multi-shard bridges: merged flushes performed and hash keys dropped
    # to capacity (E2BIG) during a merge
    shard_merges: int = 0
    merge_dropped_keys: int = 0
    # fault containment: upload retries taken, calls served by the host
    # VM after retries ran dry, writebacks deferred after a download
    # failure, and out-of-domain tuner decisions observed device-side
    # (accumulated per call, drained into this counter at flush())
    upload_retries: int = 0
    host_fallbacks: int = 0
    download_failures: int = 0
    domain_faults: int = 0


class DeviceBridge:
    """``fn(ctx_buf) -> int`` host closure with device-resident map state.

    Replaces the old full-sync bridge that round-tripped EVERY map in
    both directions on EVERY call.  Sync now happens only at the edges
    that need it:

      * **upload** — version-gated: a map is (re-)uploaded only when the
        host mutated it since the bridge last saw it (``BpfMap.version``;
        first call seeds everything).  Two bridges sharing a pinned map
        stay coherent through the host copy: one bridge's writeback
        bumps the version, the other re-uploads.
      * **download** — statically scoped: only maps the verified program
        can write (:func:`repro.core.jaxc.written_map_names`) ever sync
        back; lookup-only telemetry inputs never round-trip.  When they
        sync is the ``sync`` policy: ``"step"`` (default) writes them
        back after every call, so host maps remain the observable source
        of truth after every decision; ``"deferred"`` keeps them
        device-resident across calls — zero per-call sync in BOTH
        directions — and writes back only on :meth:`flush` (which the
        runtime triggers at every T3 boundary).
      * **flush()** — full device->host writeback.  The runtime invokes
        it at T3 boundaries (detach, ``link.replace()``, bundle reload);
        host code that mutates map values through raw ``lookup_ref``
        pointers (outside the versioned ``update``/``update_u64``/helper
        surface) should call :meth:`invalidate` to force a re-upload.

    Deferred-mode conflict rule: between flushes the device owns the
    kernel-written maps.  A host write to such a map while unflushed
    kernel writes exist cannot be merged slot-wise; the bridge keeps the
    device copy and the racing host write is DISCARDED at the next
    flush (which overwrites the whole map with device state).  Host
    code that must mutate a kernel-written map under ``"deferred"``
    coordinates explicitly: call :meth:`flush` first, then write.  Host
    writes to lookup-only maps are always picked up on the next call,
    in either mode.

    On accelerator backends the map operands are donated to the kernel
    (``donate_argnums``) so repeat calls alias device buffers instead of
    copying; CPU/interpret CI skips donation (unsupported there, and
    jax would warn on every call).

    Mesh mode (``n_shards > 1``): the bridge keeps one device-resident
    state copy PER SHARD (device/rank index, selected with
    :meth:`set_shard`), each seeded from the host maps at its own upload
    time and carrying a per-map **write cursor** (kernel calls that
    wrote the map on that shard).  Per-call writeback is meaningless
    across shards, so mesh mode requires ``sync="deferred"``; ``flush()``
    runs the versioned, conflict-free merge instead of a one-shard
    overwrite: counter slots land as the sum of per-shard deltas, EMA
    (``merge="max"``) slots go to the shard with the highest cursor, and
    hash maps reconcile per key (:mod:`repro.core.shardmerge`).  The
    merge result is bit-deterministic in shard count and order, and host
    mutations made while shards were accumulating are never lost — each
    shard contributes only deltas against its own seed snapshot.
    """

    def __init__(self, prog: Program, resolved_maps: Dict[str, BpfMap],
                 vinfo=None, *, tier: str = "pallas",
                 mode: Optional[str] = None, sync: str = "step",
                 n_shards: int = 1):
        if sync not in ("step", "deferred"):
            raise PallascError(f"unknown bridge sync policy {sync!r}; "
                               "use 'step' or 'deferred'")
        if n_shards < 1:
            raise PallascError(f"n_shards must be >= 1, got {n_shards}")
        if n_shards > 1 and sync != "deferred":
            raise PallascError(
                "multi-shard bridges accumulate per-shard deltas and merge "
                "at flush(); per-call writeback cannot reconcile shards — "
                "use sync='deferred'")
        if vinfo is None:
            vinfo = verify_with_info(prog)
        if tier == "pallas32":
            ww = 32
            fn, names = compile_pallas(prog, vinfo, mode=mode,
                                       word_width=32)
        elif tier == "pallas":
            ww = _resolve_word_width(None)
            fn, names = compile_pallas(prog, vinfo, mode=mode,
                                       word_width=ww)
        elif tier == "jaxc":
            ww = 64
            fn, names = compile_jax(prog, vinfo)
        else:
            raise PallascError(f"unknown in-graph tier {tier!r}")
        self.tier = tier
        self.word_width = ww
        self.sync = sync
        self._names = names
        self._maps = resolved_maps
        self._prog = prog
        self._written = written_map_names(prog, vinfo) & set(names)
        # fault containment: a failed upload retries with bounded
        # backoff, then the call runs on the host VM instead of raising
        self.upload_retries = 2
        self.retry_backoff_s = 0.001
        self._host_fn: Optional[Callable[[bytearray], int]] = None
        # the kernel cannot throw, so out-of-domain tuner decisions are
        # detected host-side per call and drained into stats at flush()
        self._pending_domain_faults = 0
        self._domain_offs = None
        if prog.section == "tuner":
            ct = prog.ctx_type
            try:
                self._domain_offs = (ct.offset_of("algorithm"),
                                     ct.offset_of("protocol"),
                                     ct.offset_of("n_channels"))
            except KeyError:  # pragma: no cover — tuner ctx has them
                pass
        donate = jax.default_backend() in ("tpu", "gpu")
        self._jfn = jax.jit(fn, donate_argnums=(1,)) if donate \
            else jax.jit(fn)
        self.n_shards = n_shards
        if n_shards > 1:
            from .shardmerge import MERGEABLE_KINDS
            bad = sorted(n for n in self._written
                         if prog.map_decl(n).kind not in MERGEABLE_KINDS)
            if bad:
                kinds = ", ".join(f"{n} ({prog.map_decl(n).kind})"
                                  for n in bad)
                raise PallascError(
                    f"policy '{prog.name}' writes map(s) with no order-free "
                    f"shard merge: {kinds}; mergeable kinds: "
                    f"{', '.join(MERGEABLE_KINDS)}")
        self._shard = 0
        # one device-resident state copy per shard; single-shard bridges
        # see the exact pre-mesh behavior through the property aliases
        self._devs = [dict() for _ in range(n_shards)]
        self._seens = [dict() for _ in range(n_shards)]
        self._dirtys = [set() for _ in range(n_shards)]
        # mesh mode only: per-shard seed snapshots (u64 host layout, for
        # delta merges) and per-map write cursors
        self._bases = [dict() for _ in range(n_shards)]
        self._cursors = [dict() for _ in range(n_shards)]
        self._lock = threading.Lock()
        self.stats = BridgeStats()

    # per-shard state, addressed through the currently-selected shard so
    # the call path reads identically in single- and multi-shard mode
    @property
    def _dev(self) -> Dict[str, jnp.ndarray]:
        return self._devs[self._shard]

    @_dev.setter
    def _dev(self, value: Dict[str, jnp.ndarray]) -> None:
        self._devs[self._shard] = value

    @property
    def _seen(self) -> Dict[str, int]:
        return self._seens[self._shard]

    @property
    def _device_dirty(self) -> set:
        return self._dirtys[self._shard]

    @_device_dirty.setter
    def _device_dirty(self, value: set) -> None:
        self._dirtys[self._shard] = value

    def set_shard(self, shard: int) -> None:
        """Select which shard (device/rank index) subsequent calls run
        against.  Multi-process launches call this with their rank; the
        closed-loop benchmark round-robins it to simulate per-device
        in-kernel telemetry on a single host."""
        if not 0 <= shard < self.n_shards:
            raise PallascError(
                f"shard {shard} out of range for n_shards={self.n_shards}")
        with self._lock:
            self._shard = shard

    # -- host map -> device ------------------------------------------------
    def _upload_dirty(self) -> None:
        _faults.fire("bridge_upload", self.tier)
        for n in self._names:
            m = self._maps[n]
            if n not in self._dev or self._seen.get(n) != m.version:
                if n in self._device_dirty:
                    # unflushed kernel writes: the device copy wins (see
                    # the class docstring's deferred-mode conflict rule)
                    continue
                with m.lock:
                    # snapshot + version read under ONE critical section:
                    # recording a version observed after a lock-per-entry
                    # snapshot would permanently mask a host write that
                    # landed mid-copy
                    self._dev[n] = (map_to_array32(m)
                                    if self.word_width == 32
                                    else map_to_array(m))
                    self._seen[n] = m.version
                    if self.n_shards > 1 and n in self._written:
                        # merge base: the u64 state THIS shard was seeded
                        # from — its flush contribution is a delta (or a
                        # changed-cell set) against exactly this snapshot
                        self._bases[self._shard][n] = m.to_device()
                        self._cursors[self._shard][n] = 0
                self.stats.map_uploads += 1

    # -- device -> host map ------------------------------------------------
    def _writeback(self, names) -> None:
        _faults.fire("bridge_download", self.tier)
        for n in names:
            arr = self._dev.get(n)
            if arr is None:
                continue
            m = self._maps[n]
            with m.lock:
                # our own writeback must not read as a host mutation, or
                # the next call would re-upload state the device already
                # has — record the post-writeback version under the map
                # lock so a concurrent host write is never masked
                if self.word_width == 32:
                    array32_to_map(arr, m)
                else:
                    array_to_map(arr, m)
                self._seen[n] = m.version
            self._device_dirty.discard(n)
            self.stats.map_downloads += 1

    # -- fault containment -------------------------------------------------
    def _retry_upload(self) -> bool:
        """Bounded-backoff retry of the dirty-map upload."""
        for attempt in range(self.upload_retries):
            time.sleep(self.retry_backoff_s * (attempt + 1))
            self.stats.upload_retries += 1
            try:
                self._upload_dirty()
                return True
            except Exception:
                continue
        return False

    def _host_tier_fn(self) -> Callable[[bytearray], int]:
        """Lazily-built host-VM fallback for calls whose upload failed.

        Runs against the HOST maps — the source of truth for everything
        the kernel hasn't written since the last flush.  Under
        ``sync="deferred"`` unflushed kernel writes are invisible to the
        fallback call (they reach host maps at the next healthy flush);
        that staleness is the documented deferred-mode window, not a new
        one."""
        if self._host_fn is None:
            from .vm import VM
            self._host_fn = VM(self._prog.insns, self._maps,
                               subprogs=self._prog.subprogs).run
        return self._host_fn

    # -- the runtime host-closure contract ---------------------------------
    def __call__(self, ctx_buf: bytearray) -> int:
        with self._lock:
            self.stats.calls += 1
            try:
                self._upload_dirty()
            except Exception:
                if not self._retry_upload():
                    # retries exhausted: contain the fault by running
                    # this one decision on the host tier instead of
                    # raising into the collective path
                    self.stats.host_fallbacks += 1
                    return self._host_tier_fn()(ctx_buf)
            with maybe_x64(self.word_width == 64):
                if self.word_width == 32:
                    ret, ctx_out, maps_out = self._jfn(
                        ctx_to_vec32(ctx_buf), self._dev)
                    self._dev = dict(maps_out)
                    ctx_buf[:] = vec32_to_bytes(ctx_out)
                    rv = ret32_to_int(ret)
                else:
                    import numpy as np
                    ret, ctx_out, maps_out = self._jfn(
                        ctx_to_vec(ctx_buf), self._dev)
                    self._dev = dict(maps_out)
                    ctx_buf[:] = np.asarray(ctx_out).astype("<u8").tobytes()
                    rv = int(ret)
            if self._domain_offs is not None:
                ao, po, co = self._domain_offs
                a = int.from_bytes(ctx_buf[ao:ao + 8], "little")
                p = int.from_bytes(ctx_buf[po:po + 8], "little")
                c = int.from_bytes(ctx_buf[co:co + 8], "little")
                if (a or p or c) and (a >= Algo.COUNT or p >= Proto.COUNT
                                      or c > 0xFFFFFFFF):
                    self._pending_domain_faults += 1
            if self.sync == "step":
                try:
                    self._writeback(self._written)
                except Exception:
                    # contained: host sync is deferred — keep the maps
                    # marked device-dirty so flush() retries later
                    self.stats.download_failures += 1
                    self._device_dirty |= self._written
            else:
                self._device_dirty |= self._written
                if self.n_shards > 1:
                    cur = self._cursors[self._shard]
                    for n in self._written:
                        cur[n] = cur.get(n, 0) + 1
            return rv

    def flush(self) -> int:
        """Sync every device-resident KERNEL-WRITABLE map back to the
        host maps; returns how many were written.  Called by the runtime
        at every T3 boundary (detach / replace / bundle reload).
        Lookup-only maps are never flushed — the kernel cannot have
        changed them, and writing their device copy back would silently
        revert host mutations made since the last upload."""
        with self._lock:
            _faults.fire("bridge_flush", self.tier)
            if self.n_shards > 1:
                synced = self._merged_flush()
            else:
                names = [n for n in self._names
                         if n in self._dev and n in self._written]
                self._writeback(names)
                synced = len(names)
            self.stats.flushes += 1
            # drain the per-call out-of-domain observations so the host
            # side sees kernel-tier fault events at T3 boundaries
            self.stats.domain_faults += self._pending_domain_faults
            self._pending_domain_faults = 0
            return synced

    def _merged_flush(self) -> int:
        """Mesh-mode flush: reconcile every shard's copy of each written
        map against the CURRENT host state with the deterministic shard
        merge, then drop all shard copies so the next call per shard
        re-seeds from the merged view.  Returns maps merged."""
        import numpy as np
        from . import shardmerge as _sm
        synced = 0
        for n in self._names:
            if n not in self._written:
                continue
            decl = self._prog.map_decl(n)
            shards = []
            for s in range(self.n_shards):
                arr = self._devs[s].get(n)
                if arr is None or self._cursors[s].get(n, 0) == 0:
                    continue  # never seeded, or seeded but never written
                a64 = (_sm.pairs_to_u64(arr) if self.word_width == 32
                       else np.asarray(jax.device_get(arr), dtype="<u8"))
                shards.append(_sm.Shard(s, a64, self._cursors[s][n],
                                        self._bases[s][n]))
            if not shards:
                continue
            mstats: dict = {}
            m = self._maps[n]
            with m.lock:
                merged = _sm.merge_map_shards(decl, m.to_device(), shards,
                                              mstats)
                m.from_device(merged)
            self.stats.merge_dropped_keys += mstats.get("dropped_keys", 0)
            self.stats.map_downloads += 1
            synced += 1
            # every shard copy is now stale relative to the merged host
            # state; drop them so the next per-shard call re-seeds
            for s in range(self.n_shards):
                self._devs[s].pop(n, None)
                self._seens[s].pop(n, None)
                self._dirtys[s].discard(n)
                self._bases[s].pop(n, None)
                self._cursors[s].pop(n, None)
        if synced:
            self.stats.shard_merges += 1
        return synced

    def invalidate(self, name: Optional[str] = None) -> None:
        """Drop the device copy of ``name`` (or all maps) so the next
        call re-uploads from the host — the escape hatch for host writes
        that bypass the versioned map mutation surface."""
        with self._lock:
            for s in range(self.n_shards):
                if name is None:
                    self._devs[s].clear()
                    self._seens[s].clear()
                    self._dirtys[s].clear()
                    self._bases[s].clear()
                    self._cursors[s].clear()
                else:
                    self._devs[s].pop(name, None)
                    self._seens[s].pop(name, None)
                    self._dirtys[s].discard(name)
                    self._bases[s].pop(name, None)
                    self._cursors[s].pop(name, None)


def compile_host(prog: Program, resolved_maps: Dict[str, BpfMap],
                 vinfo=None, *, tier: str = "pallas",
                 mode: Optional[str] = None,
                 sync: str = "step", n_shards: int = 1) -> DeviceBridge:
    """Wrap an in-graph tier (pallas / pallas32 / jaxc) behind the host
    closure signature ``fn(ctx_buf) -> int`` the runtime invokes.

    Returns a :class:`DeviceBridge`: map state stays device-resident
    across calls with version-gated uploads and statically-scoped
    writebacks, and the function is jitted once at load — repeat
    decisions replay the compiled kernel with zero retraces and, when
    host maps are clean, zero map uploads (``sync="deferred"`` also
    skips the per-call writeback of kernel-written maps; the state then
    reaches host maps at ``flush()``/T3 boundaries).

    ``n_shards > 1`` builds a mesh-mode bridge (one device-resident
    state copy per shard, selected with :meth:`DeviceBridge.set_shard`;
    ``flush()`` runs the deterministic shard merge) — requires
    ``sync="deferred"``."""
    return DeviceBridge(prog, resolved_maps, vinfo, tier=tier, mode=mode,
                        sync=sync, n_shards=n_shards)
