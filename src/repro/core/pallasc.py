"""pallasc — verified policy bytecode lowered to ONE Pallas kernel.

The fourth execution tier.  The ladder so far: the interpreter (ground
truth), the host JIT (v1/v2 Python closures), and jaxc (pure-JAX
if-conversion fused into the step program).  jaxc already removed host
round-trips, but its lowering emits free-floating jnp ops that XLA may
schedule anywhere; this tier packages the whole verified decision —
including PR 3's bounded loops — into a single :func:`pl.pallas_call`
kernel with explicit BlockSpec/VMEM tiling, so on-TPU the policy runs as
one fused kernel whose operands (ctx vector + array-map state) are
VMEM-resident for the duration of the decision.  Host marginal cost per
decision is zero: the host neither computes nor copies anything once the
step is dispatched.

Lowering path (shared with jaxc by construction):

  * the verifier's artifacts — shared CFG, proven ``loop_bounds``,
    per-insn region info — drive the same predicated block-by-block
    lowering (:class:`repro.core.jaxc._Lowerer`): forward regions
    if-convert, each natural loop becomes one ``lax.fori_loop`` running
    exactly ``bound + 1`` header visits,
  * pallasc wraps that body in a Pallas kernel: ctx and every array map
    are kernel operands with full-block BlockSpecs (decision state is
    tiny — a policy ctx is ~11 u64 fields, maps are KiB-scale — so one
    grid step owns everything, fully VMEM-resident),
  * outputs (return value, ctx out, updated map state) are kernel
    results, functionally threaded exactly like jaxc so closed-loop
    adaptation keeps ZERO retraces across decisions.

Backends: on TPU the kernel compiles through Mosaic; on CPU (CI) the
same ``pallas_call`` runs in interpret mode — identical lowering path,
executed by the Pallas interpreter.  ``mode="jit"`` bypasses the kernel
harness entirely and jits the bare lowering body (the pure-JAX fallback
for builds without a working Pallas).

Constraints (inherited from the in-graph surface, enforced at compile):
array maps with 8-aligned values only; helpers limited to
map_lookup_elem / map_update_elem / ema_update; 64-bit state requires
the scoped x64 context (``repro.compat.enable_x64``) around the call
boundary.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..compat import enable_x64
from .jaxc import (JaxcError, _Lowerer, array_to_map, check_supported,
                   ctx_to_vec, map_to_array)
from .maps import BpfMap
from .program import Program
from .verifier import verify_with_info

try:  # pallas is present on every jax build we target, but stay graceful
    from jax.experimental import pallas as pl
    HAVE_PALLAS = True
except Exception:  # pragma: no cover — exercised only on exotic builds
    pl = None
    HAVE_PALLAS = False


class PallascError(Exception):
    pass


def _resolve_mode(mode: Optional[str]) -> str:
    if mode is None:
        mode = "pallas" if HAVE_PALLAS else "jit"
    if mode not in ("pallas", "jit"):
        raise PallascError(f"unknown pallasc mode {mode!r}; "
                           "use 'pallas' or 'jit'")
    if mode == "pallas" and not HAVE_PALLAS:
        raise PallascError("this jax build has no importable Pallas; "
                           "use mode='jit' (the pure-JAX fallback)")
    return mode


def compile_pallas(prog: Program, vinfo=None, *, mode: Optional[str] = None,
                   interpret: Optional[bool] = None):
    """Return (fn, map_names) — the jaxc calling convention.

    ``fn(ctx_vec, map_arrays) -> (ret, ctx_vec_out, map_arrays_out)``,
    pure and jit-safe; ``ctx_vec`` is uint64[n_fields], ``map_arrays``
    maps name -> uint64[max_entries, value_slots].

    ``vinfo`` reuses a prior :func:`verify_with_info` result (shared
    cfg / loop_bounds / max_steps / region info) — the runtime's load
    path verifies once and hands the artifacts down.  ``mode=None``
    auto-selects the Pallas kernel when available, the pure-JAX body
    otherwise; ``interpret=None`` compiles through Mosaic on TPU and the
    Pallas interpreter elsewhere (same lowering path either way).
    """
    try:
        check_supported(prog)
    except JaxcError as e:
        raise PallascError(
            f"policy '{prog.name}' cannot lower to the pallas tier: {e}"
        ) from e
    if vinfo is None:
        vinfo = verify_with_info(prog)
    mode = _resolve_mode(mode)
    names = [d.name for d in prog.maps]

    if mode == "jit":
        # pure-JAX fallback: the identical _Lowerer body, no kernel harness
        def fn(ctx_vec, map_arrays: Dict[str, jnp.ndarray]):
            with enable_x64(True):
                return _Lowerer(prog, vinfo,
                                jnp.asarray(ctx_vec, jnp.uint64),
                                map_arrays).run()
        return fn, names

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _build_pallas_fn(prog, vinfo, interpret), names


def _build_pallas_fn(prog: Program, vinfo, interpret: bool) -> Callable:
    """One ``pl.pallas_call``: ctx + every array map in, (ret, ctx, maps)
    out, all as full-block VMEM tiles (house style: explicit BlockSpecs
    with an index map per operand; grid=(1,) — the whole decision state
    fits one grid step's VMEM by the verifier's bounded-state guarantee:
    ctx is n_fields*8 bytes, maps are bounded by their declarations)."""
    decls = list(prog.maps)
    names = [d.name for d in decls]
    n_maps = len(names)
    n_fields = prog.ctx_type.size // 8

    def kernel(*refs):
        ctx_ref = refs[0]
        map_refs = refs[1:1 + n_maps]
        ret_ref = refs[1 + n_maps]
        ctx_out_ref = refs[2 + n_maps]
        out_map_refs = refs[3 + n_maps:]
        ctx = ctx_ref[...]
        maps = {n: r[...] for n, r in zip(names, map_refs)}
        ret, ctx_out, maps_out = _Lowerer(prog, vinfo, ctx, maps).run()
        ret_ref[...] = jnp.reshape(ret, (1,))
        ctx_out_ref[...] = ctx_out
        for n, r in zip(names, out_map_refs):
            r[...] = maps_out[n]

    vec_spec = pl.BlockSpec((n_fields,), lambda i: (0,))
    map_specs = [pl.BlockSpec((d.max_entries, d.value_size // 8),
                              lambda i: (0, 0)) for d in decls]
    call = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[vec_spec] + map_specs,
        out_specs=(pl.BlockSpec((1,), lambda i: (0,)), vec_spec,
                   *map_specs),
        out_shape=(jax.ShapeDtypeStruct((1,), jnp.uint64),
                   jax.ShapeDtypeStruct((n_fields,), jnp.uint64),
                   *[jax.ShapeDtypeStruct((d.max_entries,
                                           d.value_size // 8), jnp.uint64)
                     for d in decls]),
        interpret=interpret,
    )

    def fn(ctx_vec, map_arrays: Dict[str, jnp.ndarray]):
        with enable_x64(True):
            args = [jnp.asarray(ctx_vec, jnp.uint64)]
            args += [jnp.asarray(map_arrays[n], jnp.uint64) for n in names]
            out = call(*args)
            return out[0][0], out[1], dict(zip(names, out[2:]))
    return fn


# ---------------------------------------------------------------------------
# Host bridge — the PolicyRuntime load/invoke contract for in-graph tiers
# ---------------------------------------------------------------------------

def compile_host(prog: Program, resolved_maps: Dict[str, BpfMap],
                 vinfo=None, *, tier: str = "pallas",
                 mode: Optional[str] = None) -> Callable[[bytearray], int]:
    """Wrap an in-graph tier (pallas or jaxc) behind the host closure
    signature ``fn(ctx_buf) -> int`` the runtime invokes.

    Map state is donated into the kernel as operands and written back
    into the host maps after each call, so the registry stays the
    cross-plugin source of truth and the differential harnesses can
    compare map state across all four tiers.  The function is jitted
    once at load: repeat decisions replay the compiled kernel with zero
    retraces (the per-call cost is the host<->device state bridge, which
    disappears entirely when the caller keeps the state in-graph via
    :class:`repro.collectives.ingraph.InGraphSelector`)."""
    import numpy as np

    if tier == "pallas":
        fn, names = compile_pallas(prog, vinfo, mode=mode)
    elif tier == "jaxc":
        from .jaxc import compile_jax
        fn, names = compile_jax(prog, vinfo)
    else:
        raise PallascError(f"unknown in-graph tier {tier!r}")
    jfn = jax.jit(fn)

    def run(ctx_buf: bytearray) -> int:
        with enable_x64(True):
            arrays = {n: map_to_array(resolved_maps[n]) for n in names}
            ret, ctx_out, maps_out = jfn(ctx_to_vec(ctx_buf), arrays)
            ctx_buf[:] = np.asarray(ctx_out).astype("<u8").tobytes()
            for n in names:
                array_to_map(maps_out[n], resolved_maps[n])
            return int(ret)
    return run
