"""Shared control-flow-graph analysis for repro policy bytecode.

One CFG layer serves all four execution tiers:

* the **verifier** classifies back edges (natural vs irreducible) and walks
  natural loops to prove trip bounds,
* the **host JIT** (v2 structured codegen) reconstructs nested ``if``/
  ``else``/``while`` regions from the post-dominator tree,
* **jaxc** lowers each natural loop to one ``lax.fori_loop`` over the
  loop's block set,
* the **interpreter** needs nothing from here at runtime, but the
  verifier-derived step bound that feeds its fuel check is computed from
  this loop nest.

Before this module existed each tier re-derived block structure privately
(the verifier scanned jumps, the JIT had its own ``_Blocks``/post-dominator
tree, jaxc leaned on pc ordering).  Loops made that untenable: back-edge
classification, loop membership and the forward (acyclic) view must agree
everywhere, or the tiers diverge on exactly the programs where divergence
is dangerous.

Graph model
-----------
Basic blocks are maximal straight-line instruction runs; block indices are
ordered by start pc.  ``succs`` holds *real* successors (``EXIT`` = -1 for
``exit``).  A **back edge** is an edge to a block that does not start at a
higher pc (a retreating edge in the linear layout).  A back edge whose
target dominates its source closes a **natural loop**; any other
retreating edge is **irreducible** control flow, which no tier supports
(the verifier rejects it).  Because every accepted non-back edge strictly
increases the start pc, block-index order is a topological order of the
forward CFG — tiers exploit this for single-pass processing.

Post-dominators are computed on the forward CFG (back edges removed); a
latch whose only successor is its back edge post-dominates to ``EXIT``,
mirroring how ``continue`` ends an iteration the way ``return`` ends a
call.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .isa import Insn, is_jump_cond

EXIT = -1  # virtual exit node (block index)


def leaders(insns: List[Insn]) -> List[int]:
    """Start pcs of basic blocks (jump targets, fall-throughs, entry)."""
    out = {0}
    for pc, insn in enumerate(insns):
        if insn.op == "ja" or is_jump_cond(insn.op):
            out.add(pc + 1 + insn.off)
            out.add(pc + 1)
        if insn.op == "exit" and pc + 1 < len(insns):
            out.add(pc + 1)
    return sorted(x for x in out if 0 <= x < len(insns))


@dataclasses.dataclass(frozen=True)
class Loop:
    """One natural loop: all back edges sharing a header, merged."""
    header: int                                # block index
    body: frozenset                            # block indices (incl. header)
    latches: Tuple[int, ...]                   # blocks with an edge to header
    back_edge_pcs: Tuple[int, ...]             # pc of each back-edge jump
    exit_edges: Tuple[Tuple[int, int], ...]    # (src in body, tgt outside)
    parent: Optional[int] = None               # header of enclosing loop

    @property
    def exit_targets(self) -> Tuple[int, ...]:
        return tuple(sorted({t for _, t in self.exit_edges}))


class IrreducibleError(Exception):
    """A retreating edge whose target does not dominate its source."""

    def __init__(self, pc: int, src_block: int, tgt_block: int):
        self.pc = pc
        self.src_block = src_block
        self.tgt_block = tgt_block
        super().__init__(
            f"irreducible control flow: retreating edge at insn {pc} does "
            "not close a natural loop")


class CFG:
    """Basic blocks + dominators + post-dominators + natural loop nest."""

    EXIT = EXIT

    def __init__(self, insns: List[Insn]):
        self.insns = insns
        self.leaders = leaders(insns)
        self.block_of: Dict[int, int] = {pc: i for i, pc in
                                         enumerate(self.leaders)}
        self.n = len(self.leaders)
        self.ranges: List[Tuple[int, int]] = []
        self.succs: List[List[int]] = []
        for bi, start in enumerate(self.leaders):
            end = self.leaders[bi + 1] if bi + 1 < self.n else len(insns)
            self.ranges.append((start, end))
            last = insns[end - 1]
            if last.op == "exit":
                self.succs.append([EXIT])
            elif last.op == "ja":
                self.succs.append([self._tgt(end - 1, last)])
            elif is_jump_cond(last.op):
                self.succs.append([self._tgt(end - 1, last), bi + 1])
            else:
                self.succs.append([bi + 1 if bi + 1 < self.n else EXIT])
        self.preds: List[List[int]] = [[] for _ in range(self.n)]
        for b, ss in enumerate(self.succs):
            for s in ss:
                if s != EXIT:
                    self.preds[s].append(b)

        # retreating edges: target block starts no later than the source
        self.back_edges: List[Tuple[int, int]] = [
            (u, v) for u, ss in enumerate(self.succs)
            for v in ss if v != EXIT and v <= u]
        self.fwd_succs: List[List[int]] = [
            [s for s in ss if s == EXIT or s > u]
            for u, ss in enumerate(self.succs)]

        self._build_doms()
        self._build_loops()        # may raise IrreducibleError
        self._build_pdom()

    # ---- helpers ----------------------------------------------------------
    def _tgt(self, pc: int, insn: Insn) -> int:
        t = pc + 1 + insn.off
        # a (necessarily unreachable) jump may target one-past-the-end;
        # route it to the virtual exit so the trees stay well formed
        return self.block_of.get(t, EXIT)

    def block_insns(self, b: int) -> range:
        s, e = self.ranges[b]
        return range(s, e)

    def terminator_pc(self, b: int) -> int:
        return self.ranges[b][1] - 1

    # ---- dominators (full CFG, iterative bitset) -------------------------
    def _build_doms(self) -> None:
        full = (1 << self.n) - 1
        dom = [full] * self.n
        dom[0] = 1
        changed = True
        while changed:
            changed = False
            for b in range(1, self.n):
                ps = [dom[p] for p in self.preds[b]]
                if not ps:
                    continue  # unreachable: keep the full set (vacuous
                    # domination), so a dead latch still closes its
                    # natural loop instead of reading as irreducible
                new = ps[0]
                for m in ps[1:]:
                    new &= m
                new |= (1 << b)
                if new != dom[b]:
                    dom[b] = new
                    changed = True
        self._dom_bits = dom

    def dominates(self, a: int, b: int) -> bool:
        """True iff block ``a`` dominates block ``b``."""
        return bool((self._dom_bits[b] >> a) & 1)

    # ---- natural loops ----------------------------------------------------
    def _build_loops(self) -> None:
        by_header: Dict[int, Dict[str, list]] = {}
        for u, v in self.back_edges:
            pc = self.terminator_pc(u)
            if not self.dominates(v, u):
                raise IrreducibleError(pc, u, v)
            rec = by_header.setdefault(v, {"latches": [], "pcs": [],
                                           "body": {v}})
            rec["latches"].append(u)
            rec["pcs"].append(pc)
            # classic natural-loop walk: everything reaching the latch
            # without passing the header
            work = [u]
            body = rec["body"]
            while work:
                b = work.pop()
                if b in body:
                    continue
                body.add(b)
                work.extend(p for p in self.preds[b] if p not in body)

        self.loops: Dict[int, Loop] = {}
        for h, rec in by_header.items():
            body = frozenset(rec["body"])
            exit_edges = tuple(sorted(
                (b, s) for b in body for s in self.succs[b]
                if s != EXIT and s not in body))
            self.loops[h] = Loop(
                header=h, body=body, latches=tuple(sorted(rec["latches"])),
                back_edge_pcs=tuple(sorted(rec["pcs"])),
                exit_edges=exit_edges)

        # innermost-loop map + loop nesting (smallest containing body wins)
        by_size = sorted(self.loops.values(), key=lambda L: len(L.body))
        self.loop_of_block: Dict[int, int] = {}
        for L in reversed(by_size):            # larger first, smaller wins
            for b in L.body:
                self.loop_of_block[b] = L.header
        for L in by_size:
            parent = None
            for other in by_size:
                if other.header != L.header and L.body < other.body:
                    parent = other.header
                    break                      # smallest strict superset
            if parent is not None:
                self.loops[L.header] = dataclasses.replace(L, parent=parent)

    @property
    def has_loops(self) -> bool:
        return bool(self.loops)

    def inner_loops(self, L: Loop) -> List[Loop]:
        """Loops nested directly inside ``L``."""
        return [M for M in self.loops.values() if M.parent == L.header]

    def loop_depth(self, b: int) -> int:
        d = 0
        h = self.loop_of_block.get(b)
        while h is not None:
            d += 1
            h = self.loops[h].parent
        return d

    # ---- post-dominators on the forward CFG ------------------------------
    def _build_pdom(self) -> None:
        self.ipdom: Dict[int, int] = {EXIT: EXIT}
        self.pdom_depth: Dict[int, int] = {EXIT: 0}
        for b in range(self.n - 1, -1, -1):
            ss = [s if s == EXIT or s < self.n else EXIT
                  for s in self.fwd_succs[b]]
            if not ss:
                # back-edge-only latch: an iteration's `continue` ends the
                # path the way `return` does
                ss = [EXIT]
            d = ss[0]
            for s in ss[1:]:
                d = self.ncpd(d, s)
            self.ipdom[b] = d
            self.pdom_depth[b] = self.pdom_depth[d] + 1

    def ncpd(self, a: int, b: int) -> int:
        """Nearest common post-dominator (forward CFG) of two nodes."""
        while a != b:
            if self.pdom_depth[a] < self.pdom_depth[b]:
                b = self.ipdom[b]
            else:
                a = self.ipdom[a]
        return a


# ---- multi-function programs ----------------------------------------------
# ``call_fn`` is a plain non-terminator (control always returns to the
# next insn), so a bpf-to-bpf program is a *forest* of single-entry CFGs
# — one per function — and the inter-function structure (call graph,
# recursion/depth checks) lives in the verifier, not here.

def program_cfgs(prog) -> List[CFG]:
    """One CFG per function of a Program: index 0 is main, index
    ``1 + i`` is ``prog.subprogs[i]`` (i.e. ``call_fn`` operand + 1)."""
    out = [CFG(list(prog.insns))]
    out.extend(CFG(list(sp.insns)) for sp in getattr(prog, "subprogs", ()))
    return out


def call_sites(insns: List[Insn]) -> List[Tuple[int, int]]:
    """(pc, subprog index) of every ``call_fn`` in one function body."""
    return [(pc, insn.imm) for pc, insn in enumerate(insns)
            if insn.op == "call_fn"]
