"""Tiny eBPF assembler: text mnemonics + labels -> Insn list.

Used by the safety test suite (hand-crafted unsafe programs that must hit a
precise verifier bug class) and by anyone who wants to write policies below
the restricted-Python frontend.

Syntax (one insn per line, ``;`` comments, ``label:`` on its own line)::

    mov64   r2, 123            ; imm form auto-selected
    mov64   r2, r3             ; reg form
    ldxdw   r2, [r1+8]         ; load 8 bytes from r1+8
    stxdw   [r10-16], r2       ; store reg
    stdw    [r10-16], 7        ; store imm
    lddw    r2, 0x123456789    ; 64-bit imm
    ldmap   r1, my_map         ; load map pointer
    call    map_lookup_elem    ; or: call 1
    jeq     r0, 0, out         ; cond jump to label (imm or reg form)
    ja      out
  out:
    exit

Field names may be used as load/store offsets when the section is known:
``ldxdw r2, [r1+msg_size]``.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from .context import CTX_TYPES
from .helpers import HELPER_IDS
from .isa import (Insn, LOAD_OPS, STORE_IMM_OPS, STORE_REG_OPS, is_alu,
                  is_jump_cond)
from .program import MapDecl, Program

_REG = re.compile(r"^r(\d+)$")
_MEM = re.compile(r"^\[r(\d+)([+-]\w+)?\]$")


class AsmError(Exception):
    pass


def _parse_int(tok: str) -> Optional[int]:
    try:
        return int(tok, 0)
    except ValueError:
        return None


def _split_operands(rest: str) -> List[str]:
    return [t.strip() for t in rest.split(",") if t.strip()]


def assemble(text: str, *, name: str = "prog", section: str = "tuner",
             maps: Tuple[MapDecl, ...] = ()) -> Program:
    ctx = CTX_TYPES[section]
    lines = []
    for raw in text.splitlines():
        line = raw.split(";", 1)[0].strip()
        if line:
            lines.append(line)

    # pass 1: label addresses
    labels: Dict[str, int] = {}
    pc = 0
    body: List[str] = []
    for line in lines:
        if line.endswith(":"):
            labels[line[:-1].strip()] = pc
        else:
            body.append(line)
            pc += 1

    def _field_off(tok: str) -> int:
        v = _parse_int(tok)
        if v is not None:
            return v
        if tok in ctx.fields:
            return ctx.fields[tok].offset
        raise AsmError(f"unknown offset token {tok!r}")

    def _mem(tok: str) -> Tuple[int, int]:
        m = _MEM.match(tok.replace(" ", ""))
        if not m:
            raise AsmError(f"bad memory operand {tok!r}")
        reg = int(m.group(1))
        off_tok = m.group(2) or "+0"
        sign = -1 if off_tok[0] == "-" else 1
        return reg, sign * _field_off(off_tok[1:])

    insns: List[Insn] = []
    for i, line in enumerate(body):
        parts = line.split(None, 1)
        op = parts[0]
        ops = _split_operands(parts[1]) if len(parts) > 1 else []

        if op == "exit":
            insns.append(Insn("exit"))
        elif op == "call":
            (h,) = ops
            hid = _parse_int(h)
            if hid is None:
                hid = HELPER_IDS.get(h)
                if hid is None:
                    raise AsmError(f"insn {i}: unknown helper {h!r}")
            insns.append(Insn("call", imm=hid))
        elif op == "ja":
            (lbl,) = ops
            tgt = labels.get(lbl)
            if tgt is None:
                raise AsmError(f"insn {i}: unknown label {lbl!r}")
            insns.append(Insn("ja", off=tgt - (i + 1)))
        elif op == "lddw":
            dst, imm = ops
            m = _REG.match(dst)
            insns.append(Insn("lddw", dst=int(m.group(1)), imm=_parse_int(imm)))
        elif op == "ldmap":
            dst, mname = ops
            m = _REG.match(dst)
            insns.append(Insn("ldmap", dst=int(m.group(1)), map_name=mname))
        elif op in LOAD_OPS:
            dst, mem = ops
            m = _REG.match(dst)
            base, off = _mem(mem)
            insns.append(Insn(op, dst=int(m.group(1)), src=base, off=off))
        elif op in STORE_REG_OPS:
            mem, src = ops
            base, off = _mem(mem)
            m = _REG.match(src)
            if m:
                insns.append(Insn(op, dst=base, src=int(m.group(1)), off=off))
            else:  # allow stx with imm -> rewrite to st
                insns.append(Insn("st" + op[3:], dst=base, off=off,
                                  imm=_parse_int(src)))
        elif op in STORE_IMM_OPS:
            mem, imm = ops
            base, off = _mem(mem)
            insns.append(Insn(op, dst=base, off=off, imm=_parse_int(imm)))
        elif is_jump_cond(op) or is_jump_cond(op + "i"):
            dst, other, lbl = ops
            m = _REG.match(dst)
            tgt = labels.get(lbl)
            if tgt is None:
                raise AsmError(f"insn {i}: unknown label {lbl!r}")
            off = tgt - (i + 1)
            ms = _REG.match(other)
            if ms:
                insns.append(Insn(op.rstrip("i"), dst=int(m.group(1)),
                                  src=int(ms.group(1)), off=off))
            else:
                base = op if op.endswith("i") else op + "i"
                insns.append(Insn(base, dst=int(m.group(1)), off=off,
                                  imm=_parse_int(other)))
        elif is_alu(op) or is_alu(op + "i"):
            if op.rstrip("i").startswith("neg"):
                (dst,) = ops
                m = _REG.match(dst)
                insns.append(Insn(op.rstrip("i"), dst=int(m.group(1))))
                continue
            dst, other = ops
            m = _REG.match(dst)
            ms = _REG.match(other)
            if ms:
                insns.append(Insn(op.rstrip("i"), dst=int(m.group(1)),
                                  src=int(ms.group(1))))
            else:
                base = op if op.endswith("i") else op + "i"
                val = other
                if not other.lstrip("+-").isdigit() and not other.startswith("0x"):
                    # symbolic ctx field offset as immediate
                    val = str(_field_off(other))
                insns.append(Insn(base, dst=int(m.group(1)), imm=_parse_int(val)))
        else:
            raise AsmError(f"insn {i}: cannot parse {line!r}")

    return Program(name=name, section=section, insns=insns, maps=maps,
                   source=text)
