"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper with shape handling + fallbacks
  ref.py    — pure-jnp oracle used by the allclose test sweeps

On this CPU container kernels are validated with interpret=True (the
kernel body executes in Python); on TPU the same BlockSpecs drive MXU/VMEM
tiling.  These are *framework* hot-spots, not paper contributions — the
paper's contribution (policy execution) is host/XLA-level; DESIGN.md §2.
"""

from .flash_attention.ops import flash_attention
from .grouped_matmul.ops import grouped_matmul
from .rmsnorm.ops import fused_rmsnorm

__all__ = ["flash_attention", "grouped_matmul", "fused_rmsnorm"]
