"""MoE grouped matmul Pallas kernel.

Computes per-expert (C, D) @ (D, F) with one kernel launch.  TPU
adaptation: instead of CUDA's persistent thread-blocks with a work-stealing
queue over ragged groups, the TPU grid iterates (expert, C-tile, F-tile,
D-tile) with the D (contraction) axis innermost, accumulating each (bc, bf)
output tile in VMEM scratch across D-steps — MXU-aligned 128×128 tiles.
Capacity-padded MoE buffers make groups rectangular (E × C), so no ragged
handling is needed (the dispatch layer pads to capacity; DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    dk = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(dk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)      # (bc, bd)
    w = w_ref[0].astype(jnp.float32)      # (bd, bf)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(dk == nd - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul_tpu(x, w, *, bc: int = 128, bf: int = 128,
                       bd: int = 512, interpret: bool = True):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bd = min(bc, C), min(bf, F), min(bd, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0

    return pl.pallas_call(
        _gmm_kernel,
        grid=(E, C // bc, F // bf, D // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
