"""Public grouped-matmul op."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import grouped_matmul_tpu
from .ref import grouped_matmul_ref


@partial(jax.jit, static_argnames=("backend", "bc", "bf", "bd"))
def grouped_matmul(x, w, *, backend: str = "pallas", bc: int = 128,
                   bf: int = 128, bd: int = 512):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    if backend == "ref":
        return grouped_matmul_ref(x, w)
    on_tpu = jax.devices()[0].platform == "tpu"
    return grouped_matmul_tpu(x, w, bc=bc, bf=bf, bd=bd,
                              interpret=not on_tpu)
