"""Pure-jnp oracle for the MoE grouped matmul."""

from __future__ import annotations

import jax.numpy as jnp


def grouped_matmul_ref(x, w):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F).  One matmul per expert."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
