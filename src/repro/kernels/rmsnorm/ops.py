"""Public fused-rmsnorm op."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import fused_rmsnorm_tpu
from .ref import fused_rmsnorm_ref


@partial(jax.jit, static_argnames=("eps", "backend", "bt"))
def fused_rmsnorm(x, scale, residual=None, *, eps: float = 1e-6,
                  backend: str = "pallas", bt: int = 128):
    """x: (..., D) flattened internally; returns (normed, residual_stream)."""
    if backend == "ref":
        return fused_rmsnorm_ref(x, scale, residual, eps=eps)
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    rf = residual.reshape(-1, D) if residual is not None else None
    on_tpu = jax.devices()[0].platform == "tpu"
    y, res = fused_rmsnorm_tpu(xf, scale, rf, eps=eps,
                               bt=min(bt, xf.shape[0]),
                               interpret=not on_tpu)
    return y.reshape(shape), res.reshape(shape)
