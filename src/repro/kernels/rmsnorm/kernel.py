"""Fused residual-add + RMSNorm Pallas kernel.

Memory-bound fusion: the unfused sequence (add -> square -> mean -> rsqrt
-> mul) reads/writes the (T, D) activation 3-4 times through HBM; the
fusion reads once and writes twice (normed out + updated residual stream).
Row-tiled: each grid step owns a (bt, D) tile fully resident in VMEM —
D ≤ 8192 f32 keeps the tile ≤ 4 MiB at bt=128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, res_ref, *, eps: float,
                    with_residual: bool, r_ref=None):
    x = x_ref[...].astype(jnp.float32)
    if with_residual:
        x = x + r_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype)
                  * scale_ref[...].astype(o_ref.dtype))
    res_ref[...] = x.astype(res_ref.dtype)


def fused_rmsnorm_tpu(x, scale, residual=None, *, eps: float = 1e-6,
                      bt: int = 128, interpret: bool = True):
    """x: (T, D); scale: (D,); residual: optional (T, D)."""
    T, D = x.shape
    bt = min(bt, T)
    assert T % bt == 0
    with_residual = residual is not None

    if with_residual:
        def kern(x_ref, scale_ref, r_ref, o_ref, res_ref):
            _rmsnorm_kernel(x_ref, scale_ref, o_ref, res_ref, eps=eps,
                            with_residual=True, r_ref=r_ref)
        in_specs = [
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
        ]
        args = (x, scale, residual)
    else:
        def kern(x_ref, scale_ref, o_ref, res_ref):
            _rmsnorm_kernel(x_ref, scale_ref, o_ref, res_ref, eps=eps,
                            with_residual=False)
        in_specs = [
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ]
        args = (x, scale)

    return pl.pallas_call(
        kern,
        grid=(T // bt,),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((bt, D), lambda i: (i, 0)),
                   pl.BlockSpec((bt, D), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((T, D), x.dtype),
                   jax.ShapeDtypeStruct((T, D), x.dtype)),
        interpret=interpret,
    )(*args)
