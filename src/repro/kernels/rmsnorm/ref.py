"""Pure-jnp oracle for fused RMSNorm (+ optional residual add)."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def fused_rmsnorm_ref(x, scale, residual=None, *, eps: float = 1e-6):
    """x: (..., D); scale: (D,).  Returns (y, new_residual_stream)."""
    if residual is not None:
        x = x + residual
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (xf * lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)
    return y, x
