"""Public flash-attention op: GQA head expansion + backend selection."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_tpu
from .ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "backend", "bq",
                                   "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    backend: str = "pallas", bq: int = 128, bk: int = 128):
    """q: (B, H, S, d); k/v: (B, KV, T, d) with H % KV == 0.

    backend: 'pallas' (interpret on CPU, compiled on TPU) | 'ref'.
    """
    B, H, S, d = q.shape
    KV, T = k.shape[1], k.shape[2]
    if H != KV:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if backend == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    on_tpu = jax.devices()[0].platform == "tpu"
    out = flash_attention_tpu(qf, kf, vf, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=not on_tpu)
    return out.reshape(B, H, S, d)
