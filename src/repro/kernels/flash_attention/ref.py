"""Pure-jnp oracle for flash attention (causal / sliding-window GQA)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B, H, S, d); k/v: (B, H, T, d).  Heads already kv-expanded.
    Returns (B, H, S, d) in q.dtype; math in f32."""
    B, H, S, d = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pq = jnp.arange(S)[:, None] + (T - S)   # align last query to last key
    pk = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = pk <= pq
    if window > 0:
        mask = mask & (pk > pq - window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
