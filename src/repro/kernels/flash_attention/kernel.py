"""Flash attention Pallas TPU kernel (causal / sliding-window).

TPU adaptation (vs the CUDA original): the TPU grid is *sequential* over
the trailing axis, so instead of one thread-block owning a q-tile and
looping over kv in shared memory, the kernel walks kv-tiles as grid steps
and carries the online-softmax state (m, l, acc) in VMEM scratch across
steps.  MXU alignment: block shapes are multiples of 128 in the lane dim;
the f32 accumulator lives in VMEM for the whole q-tile (bq × d floats —
the BlockSpec budget is bq·d + 2·(bq·bk) + 2·bk·d floats ≤ ~2 MiB VMEM
for the default 128/512 tiles).

Grid: (B·H, S/bq, T/bk) — kv innermost so scratch carries across it.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, bq: int, bk: int,
                 seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)              # (bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # global positions (queries aligned to the END of the key range)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (seq_k - seq_q)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF): keep weights at 0
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None, bq: int = DEFAULT_BQ,
                        bk: int = DEFAULT_BK, interpret: bool = True):
    """q: (BH, S, d); k/v: (BH, T, d) — heads pre-flattened/kv-expanded."""
    BH, S, d = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)

    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, seq_q=S, seq_k=T)

    return pl.pallas_call(
        kern,
        grid=(BH, S // bq, T // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
