"""jax version-compat shims.

The public jax surface this repo leans on has drifted across releases:

* ``shard_map`` — spelled ``jax.shard_map`` on new releases, but only
  importable as ``jax.experimental.shard_map.shard_map`` on the 0.4.x
  line this container ships (the bare ``jax.shard_map`` attribute raises
  ``AttributeError`` through the deprecation machinery).
* ``enable_x64`` — the scoped 64-bit context manager is ``jax.enable_x64``
  on new releases and ``jax.experimental.enable_x64`` on 0.4.x.

Import both from here instead of from ``jax`` directly::

    from repro.compat import enable_x64, shard_map

``have_x64()`` probes (once) whether the scoped context actually yields
64-bit dtypes — tests use it to skip the in-graph tier cleanly on builds
where neither spelling works.
"""

from __future__ import annotations

import jax

def _adapt_shard_map(fn):
    """Translate the ``check_vma`` kwarg (new spelling) to ``check_rep``
    (0.4.x spelling) when the underlying shard_map predates the rename."""
    import functools
    import inspect
    try:
        params = set(inspect.signature(fn).parameters)
    except (TypeError, ValueError):  # pragma: no cover — exotic builds
        return fn
    if "check_vma" in params or "check_rep" not in params:
        return fn

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return fn(*args, **kwargs)
    return wrapped


try:  # new spelling first: jax.shard_map (>= 0.5)
    shard_map = jax.shard_map
    if not callable(shard_map):  # pragma: no cover — defensive
        raise AttributeError("jax.shard_map is not callable")
except AttributeError:
    from jax.experimental.shard_map import shard_map
shard_map = _adapt_shard_map(shard_map)

try:  # new spelling: jax.enable_x64
    enable_x64 = jax.enable_x64
    if not callable(enable_x64):  # pragma: no cover — defensive
        raise AttributeError("jax.enable_x64 is not callable")
except AttributeError:
    from jax.experimental import enable_x64  # noqa: F401

try:  # new spelling: jax.lax.axis_size
    axis_size = jax.lax.axis_size
    if not callable(axis_size):  # pragma: no cover — defensive
        raise AttributeError("jax.lax.axis_size is not callable")
except AttributeError:
    def axis_size(axis_name):
        """Static size of a named mesh axis (0.4.x spelling)."""
        from jax import core
        frame = core.axis_frame(axis_name)
        return getattr(frame, "size", frame)

def maybe_x64(needed: bool = True):
    """``enable_x64(True)`` scope when ``needed``, a no-op otherwise.

    The 32-bit-pair policy lowering (``word_width=32`` — every u64 as a
    (lo, hi) uint32 pair, the Mosaic-compilable representation) never
    touches 64-bit dtypes, so its compile/execute path must not drag the
    x64 machinery in; callers that serve both word widths scope with
    ``maybe_x64(word_width == 64)``."""
    import contextlib
    return enable_x64(True) if needed else contextlib.nullcontext()


_HAVE_X64 = None


def have_x64() -> bool:
    """True iff ``with enable_x64(True):`` really yields uint64 arrays."""
    global _HAVE_X64
    if _HAVE_X64 is None:
        try:
            import jax.numpy as jnp
            with enable_x64(True):
                _HAVE_X64 = jnp.asarray(1, jnp.uint64).dtype == jnp.uint64
        except Exception:
            _HAVE_X64 = False
    return bool(_HAVE_X64)
