"""Batched continuous-batching decode engine.

Fixed-slot design (vLLM-style static batching): B slots, each holding one
request's KV cache region.  New requests claim free slots, prompts are
prefilled token-by-token through the same decode step (single compiled
program — no prefill/decode executable switch on CPU-scale demos), then
generation proceeds; finished slots free immediately and the next queued
request claims them mid-flight (continuous batching).

The decode step is the policy-dispatched sharded step from
repro.train.step.make_serve_step when a mesh is provided; on a single
device it calls the model directly.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_params
from ..models.config import ModelConfig
from ..models.layers import MeshAxes
from ..models.transformer import init_caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    done_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.done_at is not None


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 4
    max_ctx: int = 256
    eos_id: int = -1          # -1: only stop on max_new


class EngineStallError(RuntimeError):
    """``run_until_drained`` hit its step budget with work still in
    flight.  Carries enough to debug the stall: the step count plus the
    request ids still occupying slots and still queued."""

    def __init__(self, steps: int, active_rids: List[int],
                 queued_rids: List[int]):
        self.steps = steps
        self.active_rids = active_rids
        self.queued_rids = queued_rids
        super().__init__(
            f"engine stalled after {steps} steps: "
            f"active requests {active_rids}, queued {queued_rids}")


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, ax: MeshAxes,
                 scfg: ServeConfig):
        self.cfg = cfg
        self.ax = ax
        self.scfg = scfg
        self.params = params
        B = scfg.batch_slots
        self.caches = init_caches(params, cfg, B, scfg.max_ctx, ax)
        self.pos = np.zeros((B,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * B
        self.slot_phase = ["free"] * B          # free | prefill | gen
        self.slot_cursor = np.zeros((B,), np.int32)
        self.queue: "collections.deque[Request]" = collections.deque()
        self._rid = itertools.count()
        self.steps = 0

        self._step = jax.jit(
            lambda p, t, c, q: decode_step(p, t, c, q, cfg, ax))

    # -- API -----------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 16) -> Request:
        r = Request(rid=next(self._rid), prompt=list(prompt),
                    max_new=max_new, submitted_at=time.perf_counter())
        self.queue.append(r)
        return r

    def _admit(self):
        for b in range(self.scfg.batch_slots):
            if self.slot_phase[b] == "free" and self.queue:
                r = self.queue.popleft()
                self.slot_req[b] = r
                self.slot_phase[b] = "prefill"
                self.slot_cursor[b] = 0
                self.pos[b] = 0
                self._reset_slot_cache(b)

    def _reset_slot_cache(self, b: int):
        def reset(leaf):
            if leaf.ndim == 0:
                return leaf
            return leaf.at[b].set(jnp.zeros_like(leaf[b]))
        # attention caches store pos=-1 sentinels
        new = []
        for c in self.caches:
            if isinstance(c, dict) and "pos" in c:
                c = dict(c)
                c["k"] = c["k"].at[b].set(0)
                c["v"] = c["v"].at[b].set(0)
                c["pos"] = c["pos"].at[b].set(-1)
                new.append(c)
            else:
                new.append(jax.tree.map(reset, c))
        self.caches = new

    def step(self):
        """One engine tick: admit, build the token batch, decode, route."""
        self._admit()
        B = self.scfg.batch_slots
        toks = np.zeros((B, 1), np.int32)
        for b in range(B):
            r = self.slot_req[b]
            if r is None:
                continue
            if self.slot_phase[b] == "prefill":
                toks[b, 0] = r.prompt[self.slot_cursor[b]]
            else:
                toks[b, 0] = r.out[-1] if r.out else r.prompt[-1]
        nxt, self.caches = self._step(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.pos))
        nxt = np.asarray(nxt)
        self.steps += 1

        for b in range(B):
            r = self.slot_req[b]
            if r is None:
                continue
            self.pos[b] += 1
            if self.slot_phase[b] == "prefill":
                self.slot_cursor[b] += 1
                if self.slot_cursor[b] >= len(r.prompt):
                    self.slot_phase[b] = "gen"
                    r.out.append(int(nxt[b, 0]))
            else:
                r.out.append(int(nxt[b, 0]))
                if len(r.out) >= r.max_new or \
                        (self.scfg.eos_id >= 0 and
                         r.out[-1] == self.scfg.eos_id):
                    r.done_at = time.perf_counter()
                    self.slot_req[b] = None
                    self.slot_phase[b] = "free"

    def run_until_drained(self, *, max_steps: int = 10_000,
                          on_stall: str = "raise") -> int:
        """Tick until every request completes.  Hitting ``max_steps``
        with requests still in flight is a stall, not a drain — it
        raises :class:`EngineStallError` naming the stuck request ids
        (pass ``on_stall="return"`` for the legacy silent behavior)."""
        while (self.queue or any(p != "free" for p in self.slot_phase)) \
                and self.steps < max_steps:
            self.step()
        if self.queue or any(p != "free" for p in self.slot_phase):
            if on_stall == "raise":
                raise EngineStallError(
                    self.steps,
                    [r.rid for r in self.slot_req if r is not None],
                    [r.rid for r in self.queue])
        return self.steps

    @property
    def active(self) -> int:
        return sum(p != "free" for p in self.slot_phase)
