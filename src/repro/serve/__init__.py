"""Serving substrate: batched continuous-decode engine with KV caches."""

from .engine import Request, ServeConfig, ServeEngine

__all__ = ["Request", "ServeConfig", "ServeEngine"]
