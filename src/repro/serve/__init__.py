"""Serving substrate: batched continuous-decode engine with KV caches."""

from .engine import EngineStallError, Request, ServeConfig, ServeEngine

__all__ = ["EngineStallError", "Request", "ServeConfig", "ServeEngine"]
