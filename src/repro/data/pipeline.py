"""Deterministic, sharded, prefetching LM data pipeline.

Synthetic corpus: a mixture of Zipf-distributed unigrams with injected
n-gram structure (so the loss actually decreases — pure-uniform tokens
cannot be learned).  Deterministic per (seed, step): any host can
regenerate any batch, which is what makes the pipeline resumable and
multi-host-consistent without a data service.

For VLM/audio configs the pipeline also emits stub modality inputs
(patch/frame embeddings) per DESIGN.md's frontend-stub carve-out.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    ngram_order: int = 3
    prefetch: int = 2


class SyntheticLMDataset:
    """Markov-chain synthetic text: learnable structure, measurable loss."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        rng = np.random.RandomState(dcfg.seed)
        V = cfg.vocab
        # sparse per-state transition table: each state prefers 4 successors
        self.n_states = min(4096, V)
        self.succ = rng.randint(0, V, size=(self.n_states, 4))
        self.succ_p = np.array([0.5, 0.25, 0.15, 0.1])
        ranks = np.arange(1, V + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** dcfg.zipf_a
        self.unigram = zipf / zipf.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        d, c = self.dcfg, self.cfg
        rng = np.random.RandomState((d.seed * 1_000_003 + step) % 2**31)
        B, S = d.global_batch, d.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, c.vocab, B)
        # vectorized markov walk with 20% unigram resets
        for t in range(1, S + 1):
            state = toks[:, t - 1] % self.n_states
            choice = rng.choice(4, size=B, p=self.succ_p)
            nxt = self.succ[state, choice]
            reset = rng.rand(B) < 0.2
            nxt[reset] = rng.choice(c.vocab, size=reset.sum(),
                                    p=self.unigram)
            toks[:, t] = nxt
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.family == "audio":
            out["frames"] = rng.randn(B, c.n_audio_frames,
                                      c.d_model).astype(np.float32)
        if c.family == "vlm":
            out["patches"] = rng.randn(B, c.n_patch_tokens,
                                       c.d_model).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class _Prefetcher:
    """Background-thread prefetch (host-side pipeline overlap)."""

    def __init__(self, ds: SyntheticLMDataset, depth: int, start: int = 0):
        self.ds = ds
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        while not self._stop.is_set():
            b = self.ds.batch(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        while True:
            yield self.q.get()

    def stop(self):
        self._stop.set()


def make_dataset(cfg: ModelConfig, dcfg: DataConfig, *,
                 prefetch: bool = True, start_step: int = 0):
    ds = SyntheticLMDataset(cfg, dcfg)
    if prefetch:
        return _Prefetcher(ds, dcfg.prefetch, start=start_step)
    return ds
