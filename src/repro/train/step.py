"""Sharded train/serve step construction.

The step runs entirely inside shard_map over the production mesh.  Gradient
synchronization is explicit and policy-dispatched:

  * FSDP-sharded params ('data' in spec): the AD transpose of the forward
    all-gather is a reduce-scatter over 'data' — gradients arrive already
    sharded and reduced (ZeRO-3).
  * model-replicated leaves: explicit psum over 'model' (their gradient
    contributions differ per TP rank).
  * data/pod-replicated leaves: explicit psum over 'data' / 'pod'.

All explicit psums flow through the collective dispatcher — this gradient
sync is exactly the traffic class the paper's policies tune.  The dispatcher
supports two sync modes (the §Perf hillclimb toggles them):

  bucketed=False — one psum per parameter leaf (NCCL-default-like)
  bucketed=True  — leaves are flattened into a single fused bucket per
                   (axis, reduction) class before the collective (fewer,
                   larger messages — the classic gradient-bucketing win)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

from ..collectives.dispatch import dispatcher
from ..core.context import AxisKind
from ..models import loss_fn
from ..models.config import ModelConfig
from ..models.layers import MeshAxes
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .schedule import cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    opt: AdamWConfig = AdamWConfig()
    total_steps: int = 10_000
    warmup_steps: int = 100
    bucketed_grad_sync: bool = False


def _spec_axes(spec) -> set:
    out = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.update(entry)
        else:
            out.add(entry)
    return out


def _psum(x, axis: str, kind: int):
    return dispatcher().all_reduce(x, axis, axis_kind=kind)


def sync_grads(grads, specs, ax: MeshAxes, *, bucketed: bool = False):
    """Reduce gradients per the sharding rules above."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda v: isinstance(v, P))
    assert len(flat_g) == len(flat_s), (len(flat_g), len(flat_s))

    plan = []  # (needs_model, needs_data) per leaf
    for s in flat_s:
        axes = _spec_axes(s)
        plan.append(("model" not in axes and ax.tp > 1,
                     "data" not in axes and ax.dp > 1))

    if not bucketed:
        out = []
        for g, (nm, nd) in zip(flat_g, plan):
            if nm:
                g = _psum(g, ax.model, AxisKind.MODEL)
            if nd:
                g = _psum(g, ax.data, AxisKind.DATA)
            if ax.pod:
                g = _psum(g, ax.pod, AxisKind.POD)
            out.append(g)
        flat_g = out
    else:
        # fuse same-class leaves into one flat bucket per collective
        for cls in [(True, False), (False, True), (True, True)]:
            idxs = [i for i, p in enumerate(plan) if p == cls]
            if not idxs:
                continue
            parts = [flat_g[i].reshape(-1).astype(jnp.float32)
                     for i in idxs]
            sizes = [p.size for p in parts]
            bucket = jnp.concatenate(parts)
            nm, nd = cls
            if nm:
                bucket = _psum(bucket, ax.model, AxisKind.MODEL)
            if nd:
                bucket = _psum(bucket, ax.data, AxisKind.DATA)
            off = 0
            for i, sz in zip(idxs, sizes):
                flat_g[i] = bucket[off:off + sz].reshape(
                    flat_g[i].shape).astype(flat_g[i].dtype)
                off += sz
        if ax.pod:
            parts = [g.reshape(-1).astype(jnp.float32) for g in flat_g]
            sizes = [p.size for p in parts]
            bucket = _psum(jnp.concatenate(parts), ax.pod, AxisKind.POD)
            off = 0
            for i, sz in enumerate(sizes):
                flat_g[i] = bucket[off:off + sz].reshape(
                    flat_g[i].shape).astype(flat_g[i].dtype)
                off += sz

    scale = 1.0 / (ax.dp * ax.n_pods)
    flat_g = [g * scale for g in flat_g]
    return jax.tree.unflatten(tdef, flat_g)


def batch_specs(cfg: ModelConfig, ax: MeshAxes, *, replicate_batch=False):
    dp_axes = None if replicate_batch else (
        (ax.pod, ax.data) if ax.pod else ax.data)
    s = {"tokens": P(dp_axes, None), "labels": P(dp_axes, None)}
    if cfg.family == "audio":
        s["frames"] = P(dp_axes, None, None)
    if cfg.family == "vlm":
        s["patches"] = P(dp_axes, None, None)
    return s


def make_train_step(cfg: ModelConfig, ax: MeshAxes, mesh: Mesh,
                    param_specs, step_cfg: TrainStepConfig
                    ) -> Tuple[Callable, Callable]:
    """Returns (jitted_step, opt_spec_tree).

    step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    bspecs = batch_specs(cfg, ax)
    opt_specs = {"m": param_specs, "v": param_specs, "step": P()}
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P()}

    def local_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, ax))(params)
        grads = sync_grads(grads, param_specs, ax,
                           bucketed=step_cfg.bucketed_grad_sync)
        lr_scale = cosine_schedule(opt_state["step"],
                                   step_cfg.total_steps,
                                   step_cfg.warmup_steps)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, step_cfg.opt, lr_scale)
        # metrics reduced to replicated scalars
        loss = lax.psum(loss, ax.data) / ax.dp
        if ax.pod:
            loss = lax.psum(loss, ax.pod) / ax.n_pods
        metrics["loss"] = loss
        return params, opt_state, metrics

    sm = shard_map(
        local_step, mesh=mesh,
        in_specs=(param_specs, opt_specs, bspecs),
        out_specs=(param_specs, opt_specs, metric_specs),
        check_vma=False)

    def shardings(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda v: isinstance(v, P))

    jitted = jax.jit(
        sm,
        in_shardings=(shardings(param_specs), shardings(opt_specs),
                      shardings(bspecs)),
        out_shardings=(shardings(param_specs), shardings(opt_specs),
                       shardings(metric_specs)),
        donate_argnums=(0, 1))
    return jitted, opt_specs


def make_serve_step(cfg: ModelConfig, ax: MeshAxes, mesh: Mesh,
                    param_specs, cache_specs, *, mode: str,
                    replicate_batch: bool = False):
    """mode: 'prefill' (full forward, last-pos logits) or 'decode'
    (one token against the cache).  ``replicate_batch`` serves batch
    sizes smaller than the data axis (long_500k: B=1 replicated)."""
    from ..models import decode_step, prefill

    dp_axes = None if replicate_batch else (
        (ax.pod, ax.data) if ax.pod else ax.data)

    if mode == "prefill":
        bspecs = batch_specs(cfg, ax, replicate_batch=replicate_batch)
        bspecs.pop("labels", None)     # prefill consumes tokens only
        out_spec = P(dp_axes, None, None)

        def local_prefill(params, batch):
            return prefill(params, batch, cfg, ax)

        sm = shard_map(local_prefill, mesh=mesh,
                           in_specs=(param_specs, bspecs),
                           out_specs=out_spec, check_vma=False)
        return jax.jit(sm)

    tok_spec = P(dp_axes, None)

    def local_decode(params, token, caches, pos):
        return decode_step(params, token, caches, pos, cfg, ax)

    sm = shard_map(
        local_decode, mesh=mesh,
        in_specs=(param_specs, tok_spec, cache_specs, P(dp_axes)),
        out_specs=(tok_spec, cache_specs), check_vma=False)
    return jax.jit(sm, donate_argnums=(2,))
