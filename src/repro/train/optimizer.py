"""AdamW, implemented from scratch (no optax dependency).

Optimizer state lives with the same sharding as the parameters (ZeRO: the
m/v moments inherit each param's PartitionSpec), so sharded training costs
3x param memory per shard, not per replica.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0
                 ) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
