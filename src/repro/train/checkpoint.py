"""Checkpointing: atomic, resumable, numpy-backed (no orbax dependency).

Layout: <dir>/step_<N>/
  manifest.json        — step, pytree structure, shapes/dtypes, config hash
  arrays.npz           — flattened leaves keyed by index

Writes go to a tmp dir + atomic rename (a crashed writer never corrupts the
latest checkpoint).  ``latest_step`` scans for the newest complete manifest.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, tdef = jax.tree.flatten(tree)
    return flat, tdef, jax.tree.structure(tree)


_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _encode(a: np.ndarray):
    """npz can't store ml_dtypes; view them as same-width uints."""
    name = str(a.dtype)
    if name in _EXOTIC:
        return a.view(_EXOTIC[name]), name
    return a, name


def _decode(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        import ml_dtypes
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *,
                    extra: Optional[dict] = None) -> str:
    flat, tdef = jax.tree.flatten(state)
    encoded = [_encode(np.asarray(x)) for x in flat]
    arrays = {f"a{i}": e[0] for i, e in enumerate(encoded)}
    manifest = {
        "step": int(step),
        "treedef": str(tdef),
        "n_leaves": len(flat),
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [e[1] for e in encoded],
        "extra": extra or {},
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template: Any,
                    step: Optional[int] = None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``template`` (shape-checked)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t, tdef = jax.tree.flatten(template)
    if manifest["n_leaves"] != len(flat_t):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; template has "
            f"{len(flat_t)} — config mismatch?")
    flat = []
    for i, t in enumerate(flat_t):
        a = _decode(data[f"a{i}"], manifest["dtypes"][i])
        if tuple(a.shape) != tuple(np.shape(t)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {a.shape} != template "
                f"{np.shape(t)}")
        flat.append(a)
    return jax.tree.unflatten(tdef, flat), step, manifest.get("extra", {})
