"""Trainer loop: data -> step -> metrics -> checkpoint, with live policy
hot-reload (the paper's headline operational capability) and the
profiler-plugin closed loop.

Hot-reload semantics (§T3): the trainer watches the policy runtime's epoch;
when an operator reloads a policy mid-run, the next step retraces against
the new decisions (the retrace is the TPU analogue of NCCL's communicator
warmup) — the job itself never restarts.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..collectives.dispatch import dispatcher
from ..core.context import CollType
from ..data import DataConfig, make_dataset
from ..models import init_params
from ..models.config import ModelConfig
from ..models.layers import MeshAxes
from .checkpoint import latest_step, load_checkpoint, save_checkpoint
from .optimizer import adamw_init
from .step import TrainStepConfig, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    data: DataConfig = dataclasses.field(default_factory=DataConfig)
    step: TrainStepConfig = dataclasses.field(default_factory=TrainStepConfig)
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, ax: MeshAxes, mesh: Mesh,
                 tcfg: TrainerConfig):
        self.cfg = cfg
        self.ax = ax
        self.mesh = mesh
        self.tcfg = tcfg
        self.metrics_log: List[Dict[str, float]] = []

        self.params, self.param_specs = init_params(
            jax.random.PRNGKey(tcfg.seed), cfg, ax)
        self.opt_state = adamw_init(self.params)
        self._build_step()
        self._policy_epoch = dispatcher().epoch
        self.step_idx = 0

    def _build_step(self):
        self._step_fn, self.opt_specs = make_train_step(
            self.cfg, self.ax, self.mesh, self.param_specs, self.tcfg.step)

    # -- checkpoint -----------------------------------------------------------
    def maybe_restore(self) -> bool:
        st = latest_step(self.tcfg.ckpt_dir)
        if st is None:
            return False
        state, step, _ = load_checkpoint(
            self.tcfg.ckpt_dir, {"p": self.params, "o": self.opt_state})
        self.params, self.opt_state = state["p"], state["o"]
        self.step_idx = step
        return True

    def save(self):
        save_checkpoint(self.tcfg.ckpt_dir, self.step_idx,
                        {"p": self.params, "o": self.opt_state},
                        extra={"arch": self.cfg.name})

    # -- main loop --------------------------------------------------------------
    def run(self, *, steps: Optional[int] = None) -> List[Dict[str, float]]:
        steps = steps or self.tcfg.steps
        data = make_dataset(self.cfg, self.tcfg.data,
                            start_step=self.step_idx)
        it = iter(data)
        disp = dispatcher()
        t_last = time.perf_counter()
        try:
            for _ in range(steps):
                # live policy hot-reload: epoch bump -> rebuild (retrace)
                if disp.epoch != self._policy_epoch:
                    self._policy_epoch = disp.epoch
                    self._build_step()

                batch = {k: jax.numpy.asarray(v)
                         for k, v in next(it).items()}
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step_idx += 1

                # profiler plugin feed: step latency -> shared eBPF maps
                disp.profiler_feed(
                    comm_id=0, latency_ns=int(dt * 1e9),
                    coll=CollType.ALL_REDUCE, channels=0,
                    ts_ns=time.monotonic_ns())

                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = self.step_idx
                m["step_time_s"] = dt
                self.metrics_log.append(m)
                if self.step_idx % self.tcfg.log_every == 0:
                    print(f"step {self.step_idx:6d} loss {m['loss']:.4f} "
                          f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                          f"{dt * 1e3:.0f} ms", flush=True)
                if self.tcfg.ckpt_every and \
                        self.step_idx % self.tcfg.ckpt_every == 0:
                    self.save()
        finally:
            if hasattr(data, "stop"):
                data.stop()
        return self.metrics_log
