"""Training substrate: optimizer, schedules, data, checkpointing, trainer."""

from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedule import cosine_schedule, linear_warmup
from .step import TrainStepConfig, make_train_step
from .trainer import Trainer, TrainerConfig

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "cosine_schedule", "linear_warmup", "TrainStepConfig",
           "make_train_step", "Trainer", "TrainerConfig"]
