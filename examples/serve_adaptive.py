"""Batched serving with the profiler->tuner closed loop.

    PYTHONPATH=src python examples/serve_adaptive.py

Serves a small model with continuous batching while the profiler program
streams per-step latency into a shared eBPF map and the adaptive tuner
adjusts its channel decision — the paper's §5.3 loop, attached to a real
serving engine.
"""

import time

import jax

from repro.collectives.dispatch import reset_dispatcher
from repro.configs import get_smoke_config
from repro.core.runtime import PolicyRuntime
from repro.core.context import ProfEvent, make_ctx
from repro.models import init_params
from repro.models.layers import MeshAxes
from repro.policies import adapt_profiler, adapt_tuner
from repro.serve import ServeConfig, ServeEngine

AX = MeshAxes(tp=1, dp=1, fsdp=False)


def main():
    rt = PolicyRuntime()
    rt.load(adapt_profiler.program)
    rt.load(adapt_tuner.program)
    disp = reset_dispatcher(runtime=rt)

    cfg = get_smoke_config("tinyllama-1.1b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg, AX)
    eng = ServeEngine(cfg, params, AX,
                      ServeConfig(batch_slots=4, max_ctx=96))

    reqs = [eng.submit(list(range(3 + i % 5)), max_new=12)
            for i in range(16)]
    t0 = time.perf_counter()
    ticks = 0
    while eng.queue or eng.active:
        t1 = time.perf_counter()
        eng.step()
        dt_ns = int((time.perf_counter() - t1) * 1e9)
        # profiler plugin: decode-step latency -> shared map
        rt.invoke("profiler", make_ctx(
            "profiler", event_type=ProfEvent.STEP_END, comm_id=0,
            latency_ns=dt_ns))
        ticks += 1
    wall = time.perf_counter() - t0

    done = sum(r.done for r in reqs)
    lat = [r.done_at - r.submitted_at for r in reqs if r.done]
    ctx = make_ctx("tuner", comm_id=0, msg_size=1 << 20, n_ranks=8)
    rt.invoke("tuner", ctx)
    print(f"served {done}/{len(reqs)} requests in {wall:.2f}s "
          f"({ticks} engine ticks)")
    print(f"mean request latency {sum(lat) / len(lat) * 1e3:.0f} ms")
    print(f"adaptive tuner's live channel decision: {ctx['n_channels']} "
          f"(from {rt.maps.get('adapt_map').lookup_u64(0, 2)} profiler "
          "samples)")
    sample = [r.out for r in reqs[:2]]
    print(f"sample outputs: {sample}")


if __name__ == "__main__":
    main()
