"""End-to-end training driver example: train a ~100M-param TinyLlama-family
model for a few hundred steps on CPU with a verified policy governing the
gradient-sync collectives, including a mid-run hot-reload.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]

(~100M params needs --d-model 512 --layers 12; the default is sized to
finish on this container in a few minutes — scale up if you have time.)
"""

import argparse

import jax
import numpy as np
from jax.sharding import Mesh

from repro.collectives.dispatch import reset_dispatcher
from repro.configs import get_config
from repro.core.runtime import PolicyRuntime
from repro.data import DataConfig
from repro.models.layers import MeshAxes
from repro.policies import ring_mid_v2, size_aware
from repro.train import AdamWConfig, Trainer, TrainerConfig, TrainStepConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    args = ap.parse_args()

    cfg = get_config("tinyllama-1.1b").with_overrides(
        name="tinyllama-custom", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 3, vocab=args.vocab)
    n_params = cfg.param_count()
    print(f"model: {n_params / 1e6:.1f}M params, {args.steps} steps")

    rt = PolicyRuntime()
    rt.load(size_aware.program)
    reset_dispatcher(runtime=rt)

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    tr = Trainer(cfg, MeshAxes(tp=1, dp=1, fsdp=False), mesh,
                 TrainerConfig(
                     steps=args.steps, log_every=20,
                     data=DataConfig(seq_len=args.seq,
                                     global_batch=args.batch),
                     step=TrainStepConfig(
                         opt=AdamWConfig(lr=1e-3),
                         total_steps=args.steps,
                         warmup_steps=args.steps // 10)))

    half = args.steps // 2
    log = tr.run(steps=half)
    print(f"== hot-reloading policy at step {half} (job keeps running)")
    rt.reload(ring_mid_v2.program)
    log += tr.run(steps=args.steps - half)

    first = np.mean([m["loss"] for m in log[:10]])
    last = np.mean([m["loss"] for m in log[-10:]])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first else 'NOT LEARNING'})")
    print(f"policy reloads survived: {rt.stats.reloads}, "
          f"0 lost steps, {tr.step_idx} total steps")


if __name__ == "__main__":
    main()
