"""Policy authoring tour: all three execution tiers of one verified policy.

    PYTHONPATH=src python examples/policy_authoring.py

Shows: bytecode + disassembly, the verifier's abstract interpretation
catching each bug class, and the same program running on (a) the
interpreter, (b) the host JIT, (c) jaxc — INSIDE a jitted XLA program
with map state threaded functionally (the beyond-paper tier).
"""

import jax
import jax.numpy as jnp

from repro.compat import enable_x64
from repro.core import (PolicyRuntime, VerifierError, assemble, make_ctx,
                        map_decl, policy, verify)
from repro.core.jaxc import compile_jax, ctx_to_vec, map_to_array
from repro.core.context import POLICY_CONTEXT

MiB = 1 << 20
hist = map_decl("hist", kind="array", value_size=8, max_entries=4)


@policy(section="tuner", maps=[hist])
def bucketizer(ctx):
    """Count decisions per size bucket; pick channels by bucket."""
    b = 0
    if ctx.msg_size > 1 * MiB:
        b = 1
    if ctx.msg_size > 32 * MiB:
        b = 2
    if ctx.msg_size > 256 * MiB:
        b = 3
    st = hist.lookup(b)
    if st is not None:
        st[0] = st[0] + 1
    ctx.n_channels = min(4 + b * 8, 32)
    return 0


def main():
    prog = bucketizer.program
    print(f"== compiled to {len(prog)} bytecode insns; disassembly head:")
    print("\n".join(prog.disasm().splitlines()[:8]), "\n   ...")

    verify(prog)
    print("== verifier: ACCEPTED")

    print("\n== hand-written unsafe bytecode is still caught:")
    evil = assemble("""
        mov64  r2, 1
        stxdw  [r10-520], r2
        mov64  r0, 0
        exit
    """, section="tuner")
    try:
        verify(evil)
    except VerifierError as e:
        print(f"   REJECT: {e}")

    # tier A+B: interpreter vs host JIT
    for tier, interp in [("interpreter", True), ("host JIT", False)]:
        rt = PolicyRuntime(use_interpreter=interp)
        rt.load(prog)
        ctx = make_ctx("tuner", msg_size=64 * MiB)
        rt.invoke("tuner", ctx)
        print(f"== {tier:12s}: 64 MiB -> channels={ctx['n_channels']}")

    # tier C: in-graph (jaxc) — runs inside jit with functional map state
    fn, names = compile_jax(prog)
    fields = list(POLICY_CONTEXT.fields)

    @jax.jit
    def training_step_with_policy(map_state, msg_bytes):
        vec = ctx_to_vec(make_ctx("tuner").buf)
        with enable_x64(True):
            vec = vec.at[fields.index("msg_size")].set(
                msg_bytes.astype(jnp.uint64))
        ret, vec_out, maps_out = fn(vec, {"hist": map_state})
        nch = vec_out[fields.index("n_channels")].astype(jnp.int32)
        return nch, maps_out["hist"]

    rt = PolicyRuntime()
    rt.load(prog)
    state = map_to_array(rt.maps.get("hist"))
    # x64 scope wraps the jit calls (0.4.x boundary-canonicalization rule)
    with enable_x64(True):
        for mib in (0.5, 8, 64, 512):
            nch, state = training_step_with_policy(
                state, jnp.uint32(int(mib * MiB) & 0xFFFFFFFF))
            print(f"== in-graph (jaxc): {mib:>5} MiB -> channels={int(nch)}")
    import numpy as np
    print(f"   bucket histogram carried as device state: "
          f"{[int(x) for x in np.asarray(state)[:, 0]]}")


if __name__ == "__main__":
    main()
