"""Quickstart: write a policy, verify it, watch it govern real collectives.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's full arc in one file:
  1. author a restricted-Python policy (compiled to eBPF-style bytecode)
  2. load-time verification (a buggy variant is REJECTED with the fix)
  3. the verified policy drives the framework's collective dispatch
  4. atomic hot-reload mid-run
"""

import jax

from repro.collectives.dispatch import reset_dispatcher
from repro.core import (PolicyRuntime, VerifierError, make_ctx, map_decl,
                        policy)
from repro.core.context import Algo, CollType, Proto

ALGO_RING, ALGO_TREE = Algo.RING, Algo.TREE
PROTO_SIMPLE, PROTO_LL = Proto.SIMPLE, Proto.LL
MiB = 1 << 20

# --- 1. author a policy ------------------------------------------------------
stats = map_decl("stats", kind="array", value_size=16, max_entries=8)


@policy(section="tuner", maps=[stats])
def my_tuner(ctx):
    """Small messages: latency-optimized tree; big: bandwidth ring."""
    st = stats.lookup(0)
    if st is not None:
        st[0] = st[0] + 1          # decision counter
    if ctx.msg_size <= 1 * MiB:
        ctx.algorithm = ALGO_TREE
        ctx.protocol = PROTO_LL
        ctx.n_channels = 4
    else:
        ctx.algorithm = ALGO_RING
        ctx.protocol = PROTO_SIMPLE
        ctx.n_channels = 16
    return 0


# --- 2. verification: the unsafe variant is caught at load time -------------
@policy(section="tuner", maps=[stats])
def my_buggy_tuner(ctx):
    st = stats.lookup(0)
    st[0] = st[0] + 1              # BUG: no None check
    return 0


def main():
    rt = PolicyRuntime()
    print("== loading buggy policy (must be rejected)")
    try:
        rt.load(my_buggy_tuner.program)
    except VerifierError as e:
        print(f"   VERIFIER REJECT: {e}")
    print("== loading safe policy")
    lp = rt.load(my_tuner.program)
    print(f"   verified in {lp.verify_ms:.2f} ms, JIT {lp.jit_ms:.2f} ms")

    # --- 3. the policy governs real collectives -----------------------------
    disp = reset_dispatcher(runtime=rt)
    for size_mib in (0.5, 8):
        n = int(size_mib * MiB / 4)
        d = disp.decide(CollType.ALL_REDUCE, n * 4, 8, axis_name="model")
        print(f"   {size_mib:>4} MiB -> {Algo.NAMES[d.algo]}/"
              f"{Proto.NAMES[d.proto]}/ch{d.channels}")
    print(f"   decisions counted in shared map: "
          f"{rt.maps.get('stats').lookup_u64(0, 0)}")

    # --- 4. atomic hot-reload -------------------------------------------------
    from repro.policies import bad_channels
    print("== hot-reload to bad_channels (verified but destructive)")
    rt.reload(bad_channels.program)
    d = disp.decide(CollType.ALL_REDUCE, 8 * MiB, 8, axis_name="model")
    print(f"   after reload: {Algo.NAMES[d.algo]}/ch{d.channels} "
          "(the verifier stops crashes, not bad decisions — paper §5.3)")


if __name__ == "__main__":
    main()
